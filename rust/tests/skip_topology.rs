//! Cross-stack skip-topology equivalence suite (ISSUE 5 satellite).
//!
//! For random `skips > 0` / pyramid-width manifests, the native trainer's
//! quantized eval-mode forward (the exported arithmetic mirror) must
//! bit-match every downstream inference surface: the truth-table path
//! (`luts::ModelTables`), the flattened serving engine (`LutEngine`) and
//! the synthesized-netlist engine (`NetlistEngine`).  This pins the
//! train/serve boundary against the two classic skip bugs — newest-first
//! concat ordering and quantizer-domain (maxv 1.0 input vs 2.0 hidden)
//! mismatches.

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, verify_netlist, OptLevel, SynthOpts};
use logicnets::train::{native, ModelState, TrainOpts};
use logicnets::util::prop::forall;
use logicnets::util::rng::Rng;

/// Random skip/pyramid topology on the jets shape (16 features, 5
/// classes): 1–3 hidden layers, optional taper between layers, skips 1–2.
fn random_topology(rng: &mut Rng) -> Manifest {
    let depth = 1 + rng.below(3);
    let skips = 1 + rng.below(2);
    let mut hidden = Vec::new();
    let mut w = 6 + rng.below(8);
    for _ in 0..depth {
        hidden.push(w);
        if rng.below(2) == 0 {
            w = (w / 2).max(3);
        }
    }
    let fanin = 2 + rng.below(2);
    let bw = 1 + rng.below(2);
    Manifest::synthetic_topology("skip_prop", "jets", 16, 5, &hidden, fanin, bw, skips)
}

#[test]
fn prop_trained_skip_forward_matches_tables_and_engines() {
    forall("skip-forward-equivalence", 0x5C1F, 10, |rng: &mut Rng| {
        let man = random_topology(rng);
        let seed = rng.next_u64();
        let ds = logicnets::hep::jets(300, seed ^ 1);
        let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
        let mut opts = TrainOpts::from_manifest(&man);
        // A few real steps so BN running stats, weights and biases all
        // move off their init values before the equivalence is checked.
        opts.steps = 6;
        opts.seed = seed;
        native::train_native(&man, &mut st, &ds, &opts).unwrap();

        // The trainer's eval-mode forward IS the exported mirror.
        let ex = ExportedModel::from_state(&man, &st);
        let logits = native::evaluate_native(&man, &st, &ds);
        assert_eq!(logits, ex.forward_batch(&ds.x), "eval-mode forward != mirror");

        // Mirror == truth tables on every sample (bit-exact codes; the
        // table path evaluates the same un-folded neuron arithmetic, so
        // this is an exact equality, not a tolerance check).
        let tables = ModelTables::generate(&ex).unwrap();
        assert_eq!(tables.verify(&ex, &ds.x), 0, "tables diverge from mirror");
        let lut = LutEngine::build(&ex, &tables).unwrap();

        // Synthesized netlist == truth tables (bit-exact over the whole
        // skip-concat output bus), and the netlist-backed server returns
        // the same predictions as the table engine (both share the folded
        // dense tail, so prediction equality is exact too).
        let (netlist, _) = synthesize(
            &ex,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        assert_eq!(
            verify_netlist(&ex, &tables, &netlist, 256, seed).unwrap(),
            0,
            "netlist diverges from tables"
        );
        let net = NetlistEngine::from_netlist(&ex, &tables, netlist).unwrap();
        assert_eq!(
            net.infer_batch(&ds.x),
            lut.infer_batch(&ds.x),
            "netlist engine diverges from table engine"
        );
    });
}

#[test]
fn prop_optimized_skip_netlists_stay_equivalent() {
    // The optimization pipeline (CSE + sweeps) over skip netlists: the
    // machine check inside `synthesize` must pass and the served circuit
    // must stay bit-identical to the table engine.
    forall("skip-opt-equivalence", 0x5C2F, 6, |rng: &mut Rng| {
        let man = random_topology(rng);
        let seed = rng.next_u64();
        let st = ModelState::init(&man, seed, PruneMethod::APriori);
        let ex = ExportedModel::from_state(&man, &st);
        let tables = ModelTables::generate(&ex).unwrap();
        let lut = LutEngine::build(&ex, &tables).unwrap();
        let net = NetlistEngine::build_opt(&ex, &tables, OptLevel::Full).unwrap();
        let xs: Vec<f32> = (0..16 * 80).map(|_| rng.f32()).collect();
        assert_eq!(net.infer_batch(&xs), lut.infer_batch(&xs));
    });
}
