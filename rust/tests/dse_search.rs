//! Search-engine test suite (ISSUE 3 satellite): property tests over the
//! Pareto frontier, determinism of successive halving, gate-vs-exact
//! pricing agreement, and the resume contract.

use logicnets::cost;
use logicnets::dse::search::{
    generate, run_search, Archive, CostGate, SearchAxes, SearchOpts, SearchTask, WidthShape,
};
use logicnets::dse::{pareto_frontier, DesignPoint};
use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, SynthOpts};
use logicnets::train::ModelState;
use logicnets::util::prop::{forall, small_size};
use logicnets::util::rng::Rng;

/// Strict Pareto dominance (the library's `dominated` definition).
fn dominates(q: &DesignPoint, p: &DesignPoint) -> bool {
    (q.luts <= p.luts && q.quality > p.quality)
        || (q.luts < p.luts && q.quality >= p.quality)
}

/// Best quality achievable at or below a cost, per a frontier.
fn best_at(frontier: &[DesignPoint], luts: u64) -> f64 {
    frontier
        .iter()
        .filter(|p| p.luts <= luts)
        .map(|p| p.quality)
        .fold(f64::NEG_INFINITY, f64::max)
}

fn rand_point(rng: &mut Rng, i: usize, allow_nan: bool) -> DesignPoint {
    DesignPoint {
        name: format!("p{i}"),
        luts: rng.below(1_000) as u64,
        quality: if allow_nan && rng.below(16) == 0 {
            f64::NAN
        } else {
            rng.range_f64(0.0, 100.0)
        },
    }
}

#[test]
fn prop_frontier_nondominated_and_monotone() {
    forall("frontier-nondominated", 0xD5E1, 150, |rng: &mut Rng| {
        let n = small_size(rng, 40);
        let pts: Vec<DesignPoint> =
            (0..n).map(|i| rand_point(rng, i, true)).collect();
        let f = pareto_frontier(&pts);
        // Monotone: nondecreasing cost, strictly increasing quality.
        assert!(
            f.windows(2).all(|w| w[0].luts <= w[1].luts && w[0].quality < w[1].quality),
            "frontier not monotone"
        );
        // Non-dominated against every (finite) input point.
        for p in &f {
            for q in pts.iter().filter(|q| !q.quality.is_nan()) {
                assert!(!dominates(q, p), "frontier point {p:?} dominated by {q:?}");
            }
        }
        // Every finite input point is dominated-or-equal by the frontier.
        for q in pts.iter().filter(|q| !q.quality.is_nan()) {
            assert!(best_at(&f, q.luts) >= q.quality, "{q:?} above its frontier");
        }
    });
}

#[test]
fn prop_frontier_monotone_under_insertion() {
    forall("frontier-insertion", 0xD5E2, 150, |rng: &mut Rng| {
        let n = small_size(rng, 30);
        let pts: Vec<DesignPoint> =
            (0..n).map(|i| rand_point(rng, i, false)).collect();
        let f1 = pareto_frontier(&pts);
        let mut pts2 = pts.clone();
        pts2.push(rand_point(rng, n, true));
        let f2 = pareto_frontier(&pts2);
        // Inserting a point can only improve (or keep) the best quality
        // available at every cost level.
        for probe in pts.iter().map(|p| p.luts).chain([0, 500, 1_000]) {
            assert!(
                best_at(&f2, probe) >= best_at(&f1, probe),
                "insertion worsened the frontier at cost {probe}"
            );
        }
    });
}

#[test]
fn gate_agrees_with_exact_synthesize_pricing() {
    // Small but full axis product — including the skip, pyramid-taper
    // and conv axes (16 features = a 4x4 image, so both conv lowerings
    // are real geometries here); every candidate is cross-checked against
    // the real Manifest pricing and a real synthesis run.
    let axes = SearchAxes {
        widths: vec![8, 12],
        depths: vec![1, 2],
        fanins: vec![2, 3],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0, 1, 2],
        shapes: vec![WidthShape::Rect, WidthShape::Taper { pct: 50 }],
        conv_modes: vec!["none".into(), "dense".into(), "dw".into()],
        channels: vec![2, 4],
        kernels: vec![3],
    };
    let budget = 2_000u64;
    let gate = CostGate { budget_luts: budget };
    let cands = generate(&axes, 5, usize::MAX);
    assert!(cands.iter().any(|c| c.skips > 0), "skip candidates in the pool");
    assert!(
        cands.iter().any(|c| c.hidden.windows(2).any(|w| w[0] != w[1])),
        "pyramid candidates in the pool"
    );
    assert!(cands.iter().any(|c| c.conv.is_some()), "conv candidates in the pool");
    for c in cands {
        let man = c.manifest("jets", 16, 5).unwrap();
        let exact_total = cost::total_luts(&cost::manifest_cost(&man));
        // The gate's fast-path price IS the exact analytical price...
        assert_eq!(gate.price(&c, 16, 5), exact_total, "{}", c.name());
        // ...so the gate never rejects a candidate the exact pricing
        // would accept (and never admits one it would reject).
        assert_eq!(gate.admits(gate.price(&c, 16, 5)), exact_total <= budget);
        // And the sparse-prefix share equals what `synthesize` reports as
        // the analytical bound for the mapped netlist.
        let st = ModelState::init(&man, 1, PruneMethod::APriori);
        let ex = ExportedModel::from_state(&man, &st);
        let tables = ModelTables::generate(&ex).unwrap();
        let (_, rep) = synthesize(
            &ex,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        assert_eq!(rep.analytical_luts, c.sparse_prefix_luts(16), "{}", c.name());
    }
}

fn tiny_axes() -> SearchAxes {
    SearchAxes {
        widths: vec![8, 12],
        depths: vec![1],
        fanins: vec![2],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0],
        shapes: vec![WidthShape::Rect],
        conv_modes: vec!["none".into()],
        channels: vec![4],
        kernels: vec![3],
    }
}

fn tiny_opts(dir: &str, seed: u64) -> SearchOpts {
    let out_dir = std::env::temp_dir().join(dir);
    let _ = std::fs::remove_dir_all(&out_dir);
    SearchOpts {
        budget_luts: 5_000,
        rungs: 2,
        base_steps: 6,
        eta: 2,
        seed,
        max_candidates: 4,
        out_dir,
        resume: false,
        emit: 0,
        emit_zoo: false,
    }
}

type FrontierKey = Vec<(String, u64, f64)>;

fn frontier_key(points: &[DesignPoint]) -> FrontierKey {
    points.iter().map(|p| (p.name.clone(), p.luts, p.quality)).collect()
}

#[test]
fn successive_halving_is_deterministic_for_fixed_seed() {
    let task = SearchTask::jets_small(600, 3);
    let a = run_search(&task, &tiny_axes(), &tiny_opts("lnck_dse_det_a", 9)).unwrap();
    let b = run_search(&task, &tiny_axes(), &tiny_opts("lnck_dse_det_b", 9)).unwrap();
    assert_eq!(frontier_key(&a.frontier), frontier_key(&b.frontier));
    assert_eq!(a.steps_trained, b.steps_trained);
    assert_eq!((a.admitted, a.gated), (b.admitted, b.gated));
    // A different seed must be allowed to differ (and candidate order
    // does, so trained qualities virtually always do).
    let c = run_search(&task, &tiny_axes(), &tiny_opts("lnck_dse_det_c", 10)).unwrap();
    assert_eq!(c.admitted, a.admitted, "gate decisions are seed-independent");
}

#[test]
fn resume_performs_zero_retraining_and_replays_the_frontier() {
    let task = SearchTask::jets_small(600, 7);
    let opts = tiny_opts("lnck_dse_resume", 4);
    let fresh = run_search(&task, &tiny_axes(), &opts).unwrap();
    assert!(fresh.steps_trained > 0, "fresh run must train");
    let resumed = run_search(
        &task,
        &tiny_axes(),
        &SearchOpts { resume: true, ..opts.clone() },
    )
    .unwrap();
    assert_eq!(resumed.steps_trained, 0, "resume must not retrain archived points");
    assert_eq!(frontier_key(&fresh.frontier), frontier_key(&resumed.frontier));
    // The archive on disk survives both runs and stays loadable.
    let archive = Archive::load(&fresh.archive_path).unwrap();
    assert!(!archive.entries.is_empty());
    // Changed parameters must refuse to resume rather than silently
    // diverge — including the new skip and width-shape axes, which change
    // the candidate pool just like any other axis.
    let mut skip_axes = tiny_axes();
    skip_axes.skips = vec![0, 1];
    assert!(run_search(
        &task,
        &skip_axes,
        &SearchOpts { resume: true, ..opts.clone() }
    )
    .is_err());
    let mut taper_axes = tiny_axes();
    taper_axes.shapes.push(WidthShape::Taper { pct: 50 });
    assert!(run_search(
        &task,
        &taper_axes,
        &SearchOpts { resume: true, ..opts.clone() }
    )
    .is_err());
    let incompatible = SearchOpts { resume: true, seed: 5, ..opts };
    assert!(run_search(&task, &tiny_axes(), &incompatible).is_err());
}

#[test]
fn legacy_archive_without_skip_axes_loads_and_resumes() {
    // An archive written before the skip/shape axes existed: entries carry
    // no "skips" field and the axes key has no suffix sections.  It must
    // load with skip-free / uniform-width defaults and replay under the
    // new code with zero retraining.
    let out_dir = std::env::temp_dir().join("lnck_dse_legacy_archive");
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir).unwrap();
    let entry = |name: &str, h: usize, bw: usize, luts: u64, q0: f64, q1: f64| {
        format!(
            "{{\"name\":\"{name}\",\"hidden\":[{h}],\"fanin\":2,\"bw\":{bw},\
             \"method\":\"a-priori\",\"bram_min_bits\":13,\"luts\":\"{luts}\",\
             \"status\":\"trained\",\"qualities\":[{q0},{q1}],\"accuracy\":0.5,\
             \"trained_steps\":18}}"
        )
    };
    let json = format!(
        "{{\"version\":1,\"dataset\":\"jets\",\"budget_luts\":\"5000\",\"seed\":\"4\",\
         \"rungs\":2,\"base_steps\":6,\"eta\":2,\"max_candidates\":4,\
         \"axes_key\":\"w8-12_d1_f2_b1-2_ma-priori_r13\",\"entries\":[{},{},{},{}]}}",
        entry("dse_h8_f2_b1_ap", 8, 1, 66, 51.0, 52.0),
        entry("dse_h8_f2_b2_ap", 8, 2, 93, 55.0, 56.5),
        entry("dse_h12_f2_b1_ap", 12, 1, 86, 53.0, 54.0),
        entry("dse_h12_f2_b2_ap", 12, 2, 121, 57.0, 58.25),
    );
    let archive_path = out_dir.join("archive.json");
    std::fs::write(&archive_path, json).unwrap();
    let archive = Archive::load(&archive_path).unwrap();
    assert_eq!(archive.entries.len(), 4);
    assert!(archive.entries.values().all(|e| e.skips == 0), "legacy entries default to 0");
    // Replays against the (pre-skip-default) tiny axes with zero
    // retraining: the old key still matches.
    let task = SearchTask::jets_small(600, 7);
    let opts = SearchOpts {
        budget_luts: 5_000,
        rungs: 2,
        base_steps: 6,
        eta: 2,
        seed: 4,
        max_candidates: 4,
        out_dir: out_dir.clone(),
        resume: true,
        emit: 0,
        emit_zoo: false,
    };
    assert_eq!(tiny_axes().key(), "w8-12_d1_f2_b1-2_ma-priori_r13");
    let resumed = run_search(&task, &tiny_axes(), &opts.clone()).unwrap();
    assert_eq!(resumed.steps_trained, 0, "legacy archive must replay without retraining");
    assert!(!resumed.frontier.is_empty());
    // Resuming the same archive with the new axes enabled must refuse —
    // the pool (and every promotion cut) would differ.
    let mut skip_axes = tiny_axes();
    skip_axes.skips = vec![0, 1];
    assert!(run_search(&task, &skip_axes, &opts).is_err());
}
