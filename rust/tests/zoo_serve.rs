//! Zoo-serving integration suite (ISSUE 4): budget dispatch across a
//! multi-model zoo, manifest validation, the 3-D non-domination invariant
//! of an emitted zoo, and the end-to-end explore → `zoo.json` →
//! budget-routed serving handoff.

use logicnets::dse::search::{run_search, SearchAxes, SearchOpts, SearchTask, WidthShape};
use logicnets::dse::{dominates_3d, pareto_frontier_3d};
use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::router::{Budget, ModelMeta, ZooServer};
use logicnets::serve::zoo::{build_engine, serve_zoo, ZooEntry, ZooManifest};
use logicnets::serve::{Backend, LutEngine, ServerConfig};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::util::rng::Rng;
use std::sync::Arc;

fn tiny_model(seed: u64) -> (ExportedModel, ModelTables) {
    let mut rng = Rng::new(seed);
    let neurons = (0..8)
        .map(|_| {
            let inputs = rng.choose_k(6, 3);
            Neuron {
                inputs: inputs.clone(),
                weights: inputs.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                bias: 0.0,
                g: 1.0,
                h: 0.0,
            }
        })
        .collect();
    let model = ExportedModel {
        layers: vec![ExportedLayer::uniform(
            neurons,
            6,
            QuantSpec::new(2, 1.0),
            QuantSpec::new(2, 2.0),
            true,
        )],
        in_features: 6,
        classes: 8,
        skips: 0,
        act_widths: vec![6],
    };
    let tables = ModelTables::generate(&model).unwrap();
    (model, tables)
}

fn meta(name: &str, luts: u64, quality: f64, p99_us: f64) -> ModelMeta {
    ModelMeta { name: name.into(), luts, brams: 0, quality, p50_us: p99_us / 2.0, p99_us }
}

#[test]
fn mixed_budget_traffic_splits_across_models_with_correct_answers() {
    // Two distinct models behind one budget router: every response must
    // come from the engine the router claims served it.
    let (m1, t1) = tiny_model(1);
    let (m2, t2) = tiny_model(2);
    let cheap_eng = Arc::new(LutEngine::build(&m1, &t1).unwrap());
    let best_eng = Arc::new(LutEngine::build(&m2, &t2).unwrap());
    let zoo = ZooServer::start(
        vec![
            (meta("cheap", 50, 55.0, 40.0), cheap_eng.clone() as Arc<dyn Backend>),
            (meta("best", 400, 85.0, 300.0), best_eng.clone() as Arc<dyn Backend>),
        ],
        &ServerConfig { workers: 2, max_batch: 8, ..Default::default() },
    )
    .unwrap();
    let strict = Budget::latency_us(100.0);
    let mut rng = Rng::new(77);
    for k in 0..200 {
        let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
        let budget = if k % 2 == 0 { Budget::none() } else { strict };
        let (class, served_by) = zoo.infer(x.clone(), &budget).expect("response");
        let expect_eng: &LutEngine = if k % 2 == 0 { &best_eng } else { &cheap_eng };
        assert_eq!(served_by, if k % 2 == 0 { "best" } else { "cheap" });
        assert_eq!(class, expect_eng.infer_batch(&x)[0], "k={k}");
    }
    let st = zoo.stats();
    assert_eq!(st.len(), 2);
    assert_eq!(st[0].name, "cheap");
    assert_eq!(st[0].routed, 100);
    assert_eq!(st[1].routed, 100);
    assert_eq!(st[0].stats.completed + st[1].stats.completed, 200);
    assert_eq!(zoo.fallbacks(), 0);
    // An unsatisfiable budget falls back to best quality and is counted.
    let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
    let (_, served_by) = zoo.infer(x, &Budget::latency_us(0.001)).unwrap();
    assert_eq!(served_by, "best");
    assert_eq!(zoo.fallbacks(), 1);
    zoo.shutdown();
}

#[test]
fn zoo_engine_rebuild_requires_checkpoint() {
    let entry = ZooEntry {
        name: "ghost".into(),
        dataset: "jets".into(),
        in_features: 16,
        classes: 5,
        hidden: vec![8],
        fanin: 2,
        bw: 1,
        skips: 0,
        conv_mode: None,
        conv_channels: None,
        conv_kernel: None,
        checkpoint: "ckpt/ghost.r2.bin".into(),
        luts: 100,
        brams: 0,
        quality: 50.0,
        netlist_accuracy: 0.5,
        p50_us: 10.0,
        p99_us: 20.0,
    };
    let err = build_engine(&entry, std::path::Path::new("/nonexistent-zoo-dir"))
        .expect_err("missing checkpoint must fail");
    assert!(format!("{err:#}").contains("ghost"), "{err:#}");
}

#[test]
fn explore_emits_budget_servable_zoo() {
    // End to end: tiny search → emit → calibrate → zoo.json → serve_zoo
    // routes budgeted and unbudgeted requests (debug-build sized).  Every
    // candidate is skip-wired (skips=1), so the whole handoff — archive,
    // checkpoint, zoo manifest, rebuilt netlist engine — runs the
    // skip-concat path the serving stack must reproduce bit-exactly.
    let out_dir = std::env::temp_dir().join("lnck_zoo_e2e_test");
    let _ = std::fs::remove_dir_all(&out_dir);
    let task = SearchTask::jets_small(600, 21);
    let axes = SearchAxes {
        widths: vec![8, 12],
        depths: vec![1],
        fanins: vec![2],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![1],
        shapes: vec![WidthShape::Rect],
        conv_modes: vec!["none".into()],
        channels: vec![4],
        kernels: vec![3],
    };
    let opts = SearchOpts {
        budget_luts: 5_000,
        rungs: 2,
        base_steps: 6,
        eta: 2,
        seed: 21,
        max_candidates: 4,
        out_dir: out_dir.clone(),
        resume: false,
        emit: 2,
        emit_zoo: true,
    };
    let out = run_search(&task, &axes, &opts).unwrap();
    let zoo_path = out.zoo_path.expect("zoo.json written");
    assert!(zoo_path.exists());
    let zoo = ZooManifest::load(&zoo_path).unwrap();
    assert!(!zoo.entries.is_empty());
    assert_eq!(zoo.dataset, "jets");

    // Acceptance: every registered entry is non-dominated under the 3-D
    // (LUTs, quality, latency) check.
    let pts = zoo.points();
    for p in &pts {
        for q in &pts {
            assert!(!dominates_3d(q, p), "{} dominated by {}", p.name, q.name);
        }
    }
    assert_eq!(pareto_frontier_3d(&pts).len(), pts.len());

    // Latencies are calibrated measurements, never the empty-reservoir
    // 0.0 sentinel; percentile ordering holds.  Every entry carries its
    // skip axis, and rebuilding the engine from the manifest (the exact
    // `serve --zoo` path) reproduces the recorded netlist-verified
    // accuracy bit for bit.
    for e in &zoo.entries {
        assert!(e.p50_us > 0.0 && e.p99_us >= e.p50_us, "{}: {e:?}", e.name);
        assert!(e.luts > 0 && e.quality.is_finite());
        assert_eq!(e.skips, 1, "{}: skip axis must reach the zoo manifest", e.name);
        let engine = build_engine(e, &out_dir).unwrap();
        let acc = logicnets::serve::batch_accuracy(&engine, &task.test.x, &task.test.y);
        assert!(
            (acc - e.netlist_accuracy).abs() < 1e-12,
            "{}: rebuilt accuracy {acc} != recorded {}",
            e.name,
            e.netlist_accuracy
        );
    }

    // Serve the manifest: every entry rebuilds from its checkpoint into a
    // machine-verified netlist engine behind its own worker pool.
    let server = serve_zoo(
        &zoo_path,
        &ServerConfig { workers: 1, max_batch: 8, ..Default::default() },
    )
    .unwrap();
    assert_eq!(server.in_features, task.in_features);
    let x = task.test.x[..task.test.d].to_vec();
    let (_, free_model) = server.infer(x.clone(), &Budget::none()).expect("response");
    assert_eq!(free_model, server.best_model());
    // A strict latency budget equal to the cheapest model's calibrated
    // p99 deterministically routes to that model.
    let cheapest: ModelMeta = server.models()[0].clone();
    let (_, strict_model) =
        server.infer(x, &Budget::latency_us(cheapest.p99_us)).expect("response");
    assert_eq!(strict_model, cheapest.name);
    // When the zoo holds distinct cheap/best models, the two requests hit
    // two different registered models (the CI smoke gate asserts this
    // unconditionally on a larger search).
    let free_model = free_model.to_string();
    let strict_model = strict_model.to_string();
    if server.models().len() >= 2 && cheapest.name != server.best_model() {
        assert_ne!(free_model, strict_model);
    }
    let st = server.stats();
    assert_eq!(st.iter().map(|m| m.routed).sum::<u64>(), 2);
    server.shutdown();
}
