//! Cross-stack conv-topology equivalence suite (PR 9 satellite).
//!
//! For random conv manifests (dense and depthwise+pointwise stages on a
//! 4x4 single-channel view of the jets inputs), the native trainer's
//! quantized eval-mode forward (the exported arithmetic mirror) must
//! bit-match every downstream inference surface: the truth-table path
//! (`luts::ModelTables`), the flattened serving engine (`LutEngine`) and
//! the synthesized-netlist engine (`NetlistEngine`).  This pins the
//! train/serve boundary against the conv-specific failure modes —
//! receptive-field indices drifting off the pixel-major layout, untied
//! per-pixel kernels, and quantizer-domain (maxv 1.0 input vs 2.0
//! hidden) mismatches — and checks that pre-conv `archive.json` /
//! `zoo.json` files still load and resume unchanged.

use logicnets::dse::search::{run_search, Archive, SearchOpts, SearchTask, SearchAxes, WidthShape};
use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::zoo::{build_engine, ZooManifest};
use logicnets::serve::{LutEngine, NetlistEngine};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{lint_conv_model, synthesize, verify_netlist, OptLevel, SynthOpts};
use logicnets::train::{native, ModelState, TrainOpts};
use logicnets::util::prop::forall;
use logicnets::util::rng::Rng;

/// Random conv topology on the jets shape (16 features = 4x4 image, 5
/// classes): one conv stage in either mode, odd kernel, optional hidden
/// MLP layer on the flattened map.
fn random_conv_topology(rng: &mut Rng) -> Manifest {
    let mode = if rng.below(2) == 0 { "dense" } else { "dw" };
    let channels = [1 + rng.below(3)];
    let kernel = if rng.below(2) == 0 { 1 } else { 3 };
    let hidden = if rng.below(2) == 0 { vec![] } else { vec![4 + rng.below(5)] };
    let fanin = 2 + rng.below(2);
    let bw = 1 + rng.below(2);
    // Conv window subsample cap: small enough that table enumeration
    // stays cheap at either bit-width.
    let f = Some(2 + rng.below(3));
    Manifest::synthetic_conv(
        "conv_prop", "jets", 4, 1, 5, &channels, kernel, mode, f, f, &hidden, fanin, bw,
    )
    .expect("4x4 conv geometry is valid")
}

#[test]
fn prop_trained_conv_forward_matches_tables_and_engines() {
    forall("conv-forward-equivalence", 0xC0_4F, 10, |rng: &mut Rng| {
        let man = random_conv_topology(rng);
        let seed = rng.next_u64();
        let ds = logicnets::hep::jets(300, seed ^ 1);
        let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
        let mut opts = TrainOpts::from_manifest(&man);
        // A few real steps so BN running stats, the tied kernels and the
        // head all move off their init values before equivalence checks.
        opts.steps = 6;
        opts.seed = seed;
        native::train_native(&man, &mut st, &ds, &opts).unwrap();

        // The trainer's eval-mode forward IS the exported mirror.
        let ex = ExportedModel::from_state(&man, &st);
        let logits = native::evaluate_native(&man, &st, &ds);
        assert_eq!(logits, ex.forward_batch(&ds.x), "eval-mode forward != mirror");

        // The trained export honors the receptive-field contract: every
        // conv tap in range, shared windows consistent across pixels.
        let report = lint_conv_model(&man, &ex).unwrap();
        assert!(report.is_clean(), "conv lint on trained export:\n{}", report.render());

        // Mirror == truth tables on every sample (bit-exact codes).
        let tables = ModelTables::generate(&ex).unwrap();
        assert_eq!(tables.verify(&ex, &ds.x), 0, "tables diverge from mirror");
        let lut = LutEngine::build(&ex, &tables).unwrap();

        // Synthesized netlist == truth tables, and the netlist-backed
        // server returns the same predictions as the table engine.
        let (netlist, _) = synthesize(
            &ex,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        assert_eq!(
            verify_netlist(&ex, &tables, &netlist, 256, seed).unwrap(),
            0,
            "netlist diverges from tables"
        );
        let net = NetlistEngine::from_netlist(&ex, &tables, netlist).unwrap();
        assert_eq!(
            net.infer_batch(&ds.x),
            lut.infer_batch(&ds.x),
            "netlist engine diverges from table engine"
        );
    });
}

#[test]
fn prop_optimized_conv_netlists_stay_equivalent() {
    // The optimization pipeline (CSE + sweeps) over conv netlists: the
    // machine check inside `synthesize` must pass and the served circuit
    // must stay bit-identical to the table engine.
    forall("conv-opt-equivalence", 0xC0_5F, 6, |rng: &mut Rng| {
        let man = random_conv_topology(rng);
        let seed = rng.next_u64();
        let st = ModelState::init(&man, seed, PruneMethod::APriori);
        let ex = ExportedModel::from_state(&man, &st);
        let tables = ModelTables::generate(&ex).unwrap();
        let lut = LutEngine::build(&ex, &tables).unwrap();
        let net = NetlistEngine::build_opt(&ex, &tables, OptLevel::Full).unwrap();
        let xs: Vec<f32> = (0..16 * 80).map(|_| rng.f32()).collect();
        assert_eq!(net.infer_batch(&xs), lut.infer_batch(&xs));
    });
}

#[test]
fn pre_conv_archive_and_zoo_still_load_and_resume() {
    // Fixtures written before the conv axes existed: no conv_* keys
    // anywhere.  The archive must load with conv-free defaults and replay
    // under the new code with zero retraining; the zoo manifest must load
    // with `None` conv fields.  Asking for conv axes on the old archive
    // must refuse and name the offending axis.
    let out_dir = std::env::temp_dir().join("lnck_conv_legacy_fixtures");
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir).unwrap();
    let entry = |name: &str, h: usize, bw: usize, luts: u64, q0: f64, q1: f64| {
        format!(
            "{{\"name\":\"{name}\",\"hidden\":[{h}],\"fanin\":2,\"bw\":{bw},\
             \"method\":\"a-priori\",\"bram_min_bits\":13,\"luts\":\"{luts}\",\
             \"status\":\"trained\",\"qualities\":[{q0},{q1}],\"accuracy\":0.5,\
             \"trained_steps\":18}}"
        )
    };
    let json = format!(
        "{{\"version\":1,\"dataset\":\"jets\",\"budget_luts\":\"5000\",\"seed\":\"4\",\
         \"rungs\":2,\"base_steps\":6,\"eta\":2,\"max_candidates\":4,\
         \"axes_key\":\"w8-12_d1_f2_b1-2_ma-priori_r13\",\"entries\":[{},{},{},{}]}}",
        entry("dse_h8_f2_b1_ap", 8, 1, 66, 51.0, 52.0),
        entry("dse_h8_f2_b2_ap", 8, 2, 93, 55.0, 56.5),
        entry("dse_h12_f2_b1_ap", 12, 1, 86, 53.0, 54.0),
        entry("dse_h12_f2_b2_ap", 12, 2, 121, 57.0, 58.25),
    );
    let archive_path = out_dir.join("archive.json");
    std::fs::write(&archive_path, json).unwrap();
    let archive = Archive::load(&archive_path).unwrap();
    assert_eq!(archive.entries.len(), 4);
    assert!(
        archive.entries.values().all(|e| e.conv_mode.is_none()
            && e.conv_channels.is_none()
            && e.conv_kernel.is_none()),
        "legacy entries default to conv-free"
    );
    let axes = SearchAxes {
        widths: vec![8, 12],
        depths: vec![1],
        fanins: vec![2],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0],
        shapes: vec![WidthShape::Rect],
        conv_modes: vec!["none".into()],
        channels: vec![4],
        kernels: vec![3],
    };
    // Default conv axes add no key sections: the pre-conv key matches.
    assert_eq!(axes.key(), "w8-12_d1_f2_b1-2_ma-priori_r13");
    let task = SearchTask::jets_small(600, 7);
    let opts = SearchOpts {
        budget_luts: 5_000,
        rungs: 2,
        base_steps: 6,
        eta: 2,
        seed: 4,
        max_candidates: 4,
        out_dir: out_dir.clone(),
        resume: true,
        emit: 0,
        emit_zoo: false,
    };
    let resumed = run_search(&task, &axes, &opts.clone()).unwrap();
    assert_eq!(resumed.steps_trained, 0, "pre-conv archive must replay without retraining");
    assert!(!resumed.frontier.is_empty());
    // Sweeping the conv-mode axis changes the pool: the refusal names it.
    let mut conv_axes = axes.clone();
    conv_axes.conv_modes = vec!["none".into(), "dense".into()];
    let err = run_search(&task, &conv_axes, &opts).expect_err("conv axes on pre-conv archive");
    assert!(format!("{err:#}").contains("conv-mode"), "{err:#}");

    // A pre-conv zoo.json: entries without conv keys load as conv-free.
    let zoo_json = "{\"version\":1,\"dataset\":\"jets\",\"entries\":[\
        {\"name\":\"old\",\"dataset\":\"jets\",\"in_features\":16,\"classes\":5,\
         \"hidden\":[8],\"fanin\":2,\"bw\":1,\"skips\":0,\"checkpoint\":\"ckpt/old.bin\",\
         \"luts\":\"100\",\"brams\":0,\"quality\":55.0,\"netlist_accuracy\":0.5,\
         \"p50_us\":10.0,\"p99_us\":20.0}]}";
    let zoo_path = out_dir.join("zoo.json");
    std::fs::write(&zoo_path, zoo_json).unwrap();
    let zoo = ZooManifest::load(&zoo_path).unwrap();
    assert_eq!(zoo.entries.len(), 1);
    assert!(zoo.entries[0].conv_mode.is_none() && zoo.entries[0].conv_kernel.is_none());
}

#[test]
fn conv_candidates_reach_frontier_and_serve_bit_exactly() {
    // End to end on the acceptance path: a conv-swept tiny search trains
    // real conv candidates, puts them on the frontier, emits lint-clean
    // machine-verified checkpoints, and the zoo rebuild (the exact
    // `serve --zoo` path) reproduces the recorded accuracy bit for bit.
    let out_dir = std::env::temp_dir().join("lnck_conv_e2e_search");
    let _ = std::fs::remove_dir_all(&out_dir);
    let task = SearchTask::jets_small(600, 33);
    let axes = SearchAxes {
        widths: vec![8],
        depths: vec![1],
        fanins: vec![2],
        bws: vec![1],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0],
        shapes: vec![WidthShape::Rect],
        conv_modes: vec!["dense".into()],
        channels: vec![2, 4],
        kernels: vec![3],
    };
    let opts = SearchOpts {
        budget_luts: 5_000,
        rungs: 2,
        base_steps: 6,
        eta: 2,
        seed: 33,
        max_candidates: 2,
        out_dir: out_dir.clone(),
        resume: false,
        emit: 2,
        emit_zoo: true,
    };
    let out = run_search(&task, &axes, &opts).unwrap();
    assert!(!out.frontier.is_empty());
    assert!(
        out.frontier.iter().all(|p| p.name.contains("_cdense")),
        "conv-only pool must yield a conv frontier: {:?}",
        out.frontier
    );
    let zoo = ZooManifest::load(&out.zoo_path.expect("zoo.json written")).unwrap();
    assert!(!zoo.entries.is_empty());
    for e in &zoo.entries {
        assert_eq!(e.conv_mode.as_deref(), Some("dense"), "{}", e.name);
        assert_eq!(e.conv_kernel, Some(3), "{}", e.name);
        assert!(e.conv_channels == Some(2) || e.conv_channels == Some(4), "{}", e.name);
        // Rebuild through the shared conv constructor + receptive-field
        // lint — the served circuit is the searched circuit.
        let engine = build_engine(e, &out_dir).unwrap();
        let acc = logicnets::serve::batch_accuracy(&engine, &task.test.x, &task.test.y);
        assert!(
            (acc - e.netlist_accuracy).abs() < 1e-12,
            "{}: rebuilt accuracy {acc} != recorded {}",
            e.name,
            e.netlist_accuracy
        );
    }
}
