//! Mutation corpus for the netlist design-rule checker (ISSUE 7).
//!
//! Two directions, on real trained circuits (jets rect / skip-concat /
//! pyramid topologies through the native trainer):
//!
//! 1. **Soundness of the clean path**: every shipped netlist — unoptimized,
//!    `Structural`, `Full`, and each individual `synth/opt` pass output —
//!    must produce zero findings (Errors for intermediates, zero findings
//!    at any severity for final artifacts).
//! 2. **Sensitivity**: seeding each corruption class into a trained
//!    netlist must be flagged by exactly the rule built for it, at
//!    Error/Warn severity — structural rot that sampling-based functional
//!    verification can miss entirely.

use logicnets::hep;
use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::lint::{evaluability_errors, lint_netlist, LintOptions, LintReport};
use logicnets::synth::opt::{optimize, run_pass, Pass};
use logicnets::synth::{synthesize, BramNeuron, LutNode, Net, Netlist, OptLevel, SynthOpts};
use logicnets::train::{native, ModelState, TrainOpts};

/// Train one small jets-shaped topology and synthesize it at `opt`.
/// fanin 2 × bw 2 keeps every LUT at k <= 4, so the truth-table rules
/// (which need k < 6 headroom) always have a target.
fn trained_netlist(name: &str, hidden: &[usize], skips: usize, opt: OptLevel) -> Netlist {
    let man = Manifest::synthetic_topology(name, "jets", 16, 5, hidden, 2, 2, skips);
    let seed = 0x11A7 ^ hidden.len() as u64 ^ (skips as u64) << 8;
    let ds = hep::jets(300, seed);
    let mut st = ModelState::init(&man, seed, PruneMethod::APriori);
    let mut topts = TrainOpts::from_manifest(&man);
    topts.steps = 4;
    topts.seed = seed;
    native::train_native(&man, &mut st, &ds, &topts).unwrap();
    let ex = ExportedModel::from_state(&man, &st);
    let tables = ModelTables::generate(&ex).unwrap();
    let (netlist, _) = synthesize(
        &ex,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, opt, ..SynthOpts::default() },
    )
    .unwrap();
    netlist
}

const TOPOLOGIES: &[(&str, &[usize], usize)] = &[
    ("lint_rect", &[8, 6], 0),
    ("lint_skip", &[8, 6], 1),
    ("lint_pyramid", &[10, 5], 0),
];

fn has_rule(report: &LintReport, id: &str) -> bool {
    report.findings.iter().any(|f| f.rule.id == id)
}

/// Every clean trained netlist, at every opt level, has zero findings at
/// any severity — the deny-warn serving gates rely on exactly this.
#[test]
fn clean_trained_netlists_have_zero_findings() {
    for &(name, hidden, skips) in TOPOLOGIES {
        for opt in [OptLevel::None, OptLevel::Structural, OptLevel::Full] {
            let nl = trained_netlist(name, hidden, skips, opt);
            let report = lint_netlist(&nl, &LintOptions { opt });
            assert!(
                report.is_clean(),
                "{name} at opt {} must be clean:\n{}",
                opt.name(),
                report.render()
            );
            assert!(evaluability_errors(&nl).is_empty(), "{name} at opt {}", opt.name());
        }
    }
}

/// Each individual optimizer pass output is Error-free (intermediates may
/// carry Warns: CSE exposes duplicate fan-ins that Sweep folds), and the
/// `Full` fixed point is completely clean even through one more round.
#[test]
fn per_pass_outputs_are_lint_clean() {
    let plain = trained_netlist("lint_rect", &[8, 6], 0, OptLevel::None);
    let a = run_pass(&plain, Pass::Cse);
    let b = run_pass(&a, Pass::Sweep);
    for (label, nl) in [("cse", &a), ("sweep", &b)] {
        let report = lint_netlist(nl, &LintOptions::default());
        assert_eq!(report.errors(), 0, "{label} pass:\n{}", report.render());
    }
    // At the fixed point the passes are identities, so their outputs must
    // be warning-free too, judged at the strictest level.
    let full = trained_netlist("lint_rect", &[8, 6], 0, OptLevel::Full);
    for pass in [Pass::Cse, Pass::Sweep] {
        let out = run_pass(&full, pass);
        let report = lint_netlist(&out, &LintOptions { opt: OptLevel::Full });
        assert!(report.is_clean(), "{pass:?} on fixed point:\n{}", report.render());
    }
}

/// Seed every corruption class into a trained, fully-optimized netlist and
/// assert the matching rule fires.  Functional sampling cannot see most of
/// these (they evaluate correctly or only corrupt metadata).
#[test]
fn mutation_corpus_is_caught() {
    let clean = trained_netlist("lint_rect", &[8, 6], 0, OptLevel::Full);
    let strict = LintOptions { opt: OptLevel::Full };
    let lint = |nl: &Netlist| lint_netlist(nl, &strict);

    // Stale stored level (the PR 6 workaround class).
    let mut nl = clean.clone();
    nl.nodes[0].level += 3;
    assert!(has_rule(&lint(&nl), "stale-level"), "{}", lint(&nl).render());

    // Forward (here: self) reference — `eval` used to read silent false.
    let mut nl = clean.clone();
    nl.nodes[0].inputs[0] = Net::Node(0);
    let report = lint(&nl);
    assert!(has_rule(&report, "forward-reference"), "{}", report.render());
    assert!(!evaluability_errors(&nl).is_empty());

    // Dangling references, in a node and in an output.
    let mut nl = clean.clone();
    nl.nodes[0].inputs[0] = Net::Input(u32::MAX);
    assert!(has_rule(&lint(&nl), "input-out-of-range"), "{}", lint(&nl).render());
    let mut nl = clean.clone();
    nl.outputs[0] = Net::Node(999_999);
    assert!(has_rule(&lint(&nl), "node-out-of-range"), "{}", lint(&nl).render());

    // Truth-table garbage above 2^k: invisible to evaluation (the packed
    // index never reaches those bits) — structural analysis only.
    let mut nl = clean.clone();
    let k = nl.nodes[0].inputs.len();
    assert!(k < 6, "fanin 2 x bw 2 keeps k <= 4");
    nl.nodes[0].tt |= 1u64 << (1usize << k);
    let report = lint(&nl);
    assert!(has_rule(&report, "tt-garbage"), "{}", report.render());
    assert_eq!(report.errors(), 0, "garbage bits still evaluate:\n{}", report.render());
    nl.compile_plan(); // ... and must not block plan compilation.

    // Duplicate fan-in net.
    let mut nl = clean.clone();
    let i = nl
        .nodes
        .iter()
        .position(|n| n.inputs.len() >= 2)
        .expect("a multi-input LUT exists");
    nl.nodes[i].inputs[1] = nl.nodes[i].inputs[0];
    assert!(has_rule(&lint(&nl), "duplicate-input"), "{}", lint(&nl).render());

    // Dead LUT: flagged at the optimized levels, legitimate at None.
    let mut nl = clean.clone();
    nl.nodes.push(LutNode { inputs: vec![Net::Input(0)], tt: 0b01, level: 1 });
    assert!(has_rule(&lint(&nl), "dead-lut"), "{}", lint(&nl).render());
    let relaxed = lint_netlist(&nl, &LintOptions { opt: OptLevel::None });
    assert!(relaxed.is_clean(), "dead LUTs are legal pre-opt:\n{}", relaxed.render());

    // Fan-in past the K=6 kernel.
    let mut nl = clean.clone();
    nl.nodes[0].inputs = vec![Net::Input(0); 7];
    assert!(has_rule(&lint(&nl), "fanin-too-wide"), "{}", lint(&nl).render());

    // Constant LUT the sweep should have folded.
    let mut nl = clean.clone();
    nl.nodes[0].tt = 0;
    assert!(has_rule(&lint(&nl), "const-lut"), "{}", lint(&nl).render());

    // Layer depths that understate the real combinational depth would
    // corrupt registered-timing reports.
    let mut nl = clean.clone();
    nl.layer_depths = vec![0; nl.layer_depths.len()];
    assert!(has_rule(&lint(&nl), "layer-depths-understate"), "{}", lint(&nl).render());

    // Outputs dropped but logic left behind.
    let mut nl = clean.clone();
    nl.outputs.clear();
    assert!(has_rule(&lint(&nl), "empty-outputs"), "{}", lint(&nl).render());

    // BRAM block accounting: 2^14 x 2 bits needs 2 x 18Kb blocks, not 1.
    let mut nl = clean.clone();
    nl.brams.push(BramNeuron::opaque(14, 2, 1));
    assert!(has_rule(&lint(&nl), "bram-shape"), "{}", lint(&nl).render());
}

/// Satellite: `optimize` re-levels at its fixed point, so even a netlist
/// whose stored levels were corrupted upstream comes out with truthful
/// depth metadata — and the stale-level rule pins that.
#[test]
fn optimize_relevels_corrupted_inputs() {
    let plain = trained_netlist("lint_skip", &[8, 6], 1, OptLevel::None);
    let mut corrupted = plain.clone();
    for node in &mut corrupted.nodes {
        node.level += 7;
    }
    let (fixed, _) = optimize(&corrupted, OptLevel::Structural);
    let report = lint_netlist(&fixed, &LintOptions { opt: OptLevel::Structural });
    assert!(report.is_clean(), "{}", report.render());
    // depth() now agrees with the schedule the simulator actually builds.
    assert_eq!(fixed.depth() as usize, fixed.compile_plan().num_levels());
}
