//! Bench: bitsliced netlist simulation vs the scalar `Netlist::eval` path
//! on a 1024-sample batch (the acceptance gate for the `sim` subsystem:
//! bitsliced must be >= 10x scalar), plus the parallel word-block scaling.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::sim::{eval_netlist, BitMatrix};
use logicnets::synth::{synthesize, SynthOpts};
use logicnets::util::bench::bench_n;
use logicnets::util::rng::Rng;

fn model(widths: &[usize], in_f: usize, fanin: usize, bw: usize, seed: u64) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: rng.normal_f32(0.0, 0.1),
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

fn main() {
    let batch = 1024usize;
    for (label, widths, fanin, bw) in [
        ("hep_c-like (64,32,32) X3 BW2", vec![64usize, 32, 32], 3usize, 2usize),
        ("hep_e-like (64,64,64) X4 BW2", vec![64, 64, 64], 4, 2),
    ] {
        let m = model(&widths, 16, fanin, bw, 7);
        let tables = ModelTables::generate(&m).unwrap();
        let (netlist, rep) = synthesize(
            &m,
            &tables,
            SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
        )
        .unwrap();
        println!(
            "{label}: {} LUTs over {} inputs, depth {}",
            rep.luts, netlist.num_inputs, rep.depth
        );

        // Prepare both input representations up front so only evaluation is
        // timed.
        let mut rng = Rng::new(11);
        let mut planes = BitMatrix::new(netlist.num_inputs, batch);
        let rows: Vec<Vec<bool>> = (0..batch)
            .map(|s| {
                let bits: Vec<bool> =
                    (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
                planes.set_column(s, &bits);
                bits
            })
            .collect();

        let scalar = bench_n(&format!("scalar eval x{batch}"), 5, || {
            for row in &rows {
                std::hint::black_box(netlist.eval(row));
            }
        });
        scalar.report_throughput(batch as f64, "inf");

        let sliced = bench_n(&format!("bitsliced eval batch {batch}"), 30, || {
            std::hint::black_box(eval_netlist(&netlist, &planes));
        });
        sliced.report_throughput(batch as f64, "inf");

        let single = {
            std::env::set_var("LOGICNETS_THREADS", "1");
            let r = bench_n(&format!("bitsliced eval batch {batch} (1 core)"), 30, || {
                std::hint::black_box(eval_netlist(&netlist, &planes));
            });
            std::env::remove_var("LOGICNETS_THREADS");
            r
        };
        single.report_throughput(batch as f64, "inf");

        println!(
            "{:<44} speedup over scalar: {:.1}x all-cores, {:.1}x single-core (target >= 10x)\n",
            "",
            scalar.median_ns / sliced.median_ns,
            scalar.median_ns / single.median_ns
        );
    }
}
