//! Bench: netlist simulation throughput across the three evaluator tiers —
//! scalar `Netlist::eval`, the 64-way word path (`eval_netlist_64`, the
//! pre-wide-plane baseline), and the 256-way levelized plan
//! (`eval_plan`) — plus the fused vs unfused `NetlistEngine` serving pass
//! and the scratch-reuse (allocation) win.
//!
//! Primary subject is the jets-default synthesized model (16 features, 5
//! classes, hidden [64, 32], fan-in 3, 2-bit codes — the
//! `SearchAxes::jets_default` center point); a deeper hep_e-like circuit
//! rides along as a stress shape.  Emits `BENCH_sim.json` via
//! `util::bench::BenchReport` (see that module for the `BENCH_OUT` /
//! `BENCH_BASELINE` / `BENCH_QUICK` contract).

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::NetlistEngine;
use logicnets::sim::{eval_netlist_64, eval_plan, BitMatrix, EvalPlan, SimScratch};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, Netlist, SynthOpts};
use logicnets::train::ModelState;
use logicnets::util::bench::{bench_n, BenchReport};
use logicnets::util::rng::Rng;

fn synthesized(
    name: &str,
    in_f: usize,
    classes: usize,
    hidden: &[usize],
    fanin: usize,
    bw: usize,
) -> (ExportedModel, ModelTables, Netlist) {
    let man = Manifest::synthetic_topology(name, "jets", in_f, classes, hidden, fanin, bw, 0);
    let st = ModelState::init(&man, 7, PruneMethod::APriori);
    let model = ExportedModel::from_state(&man, &st);
    let tables = ModelTables::generate(&model).unwrap();
    let (netlist, _) = synthesize(
        &model,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() },
    )
    .unwrap();
    (model, tables, netlist)
}

fn random_planes(netlist: &Netlist, batch: usize, seed: u64) -> (BitMatrix, Vec<Vec<bool>>) {
    let mut rng = Rng::new(seed);
    let mut planes = BitMatrix::new(netlist.num_inputs, batch);
    let rows: Vec<Vec<bool>> = (0..batch)
        .map(|s| {
            let bits: Vec<bool> = (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
            planes.set_column(s, &bits);
            bits
        })
        .collect();
    (planes, rows)
}

/// Throughput tiers of one netlist: scalar (primary model only), 64-way
/// baseline, 256-way plan (reused + fresh scratch, all-core + 1-core).
/// Scenario names are batch-independent so the regression gate matches
/// them across quick/full runs.
fn sim_scenarios(
    report: &mut BenchReport,
    label: &str,
    netlist: &Netlist,
    batch: usize,
    iters: usize,
    with_scalar: bool,
) {
    let (planes, rows) = random_planes(netlist, batch, 11);
    let plan = EvalPlan::compile(netlist);
    let b = batch as f64;

    if with_scalar {
        let scalar = bench_n(&format!("scalar/{label}"), 3.max(iters / 10), || {
            for row in &rows {
                std::hint::black_box(netlist.eval(row));
            }
        });
        scalar.report_throughput(b, "inf");
        report.add(&scalar, b, "inf");
    }

    let base64 = bench_n(&format!("sim64/{label}"), iters, || {
        std::hint::black_box(eval_netlist_64(netlist, &planes));
    });
    base64.report_throughput(b, "inf");
    report.add(&base64, b, "inf");

    let mut scratch = SimScratch::default();
    let wide = bench_n(&format!("sim256/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut scratch));
    });
    wide.report_throughput(b, "inf");
    report.add(&wide, b, "inf");

    // Satellite: the allocation win from reusing scratch across calls.
    let fresh = bench_n(&format!("sim256-fresh-scratch/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut SimScratch::default()));
    });
    fresh.report_throughput(b, "inf");
    report.add(&fresh, b, "inf");

    std::env::set_var("LOGICNETS_THREADS", "1");
    let base64_1 = bench_n(&format!("sim64-1core/{label}"), iters, || {
        std::hint::black_box(eval_netlist_64(netlist, &planes));
    });
    let mut scratch1 = SimScratch::default();
    let wide_1 = bench_n(&format!("sim256-1core/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut scratch1));
    });
    std::env::remove_var("LOGICNETS_THREADS");
    base64_1.report_throughput(b, "inf");
    report.add(&base64_1, b, "inf");
    wide_1.report_throughput(b, "inf");
    report.add(&wide_1, b, "inf");

    println!(
        "{:<44} wide-plane speedup over 64-way: {:.2}x all-cores, {:.2}x single-core \
         (acceptance target >= 3x); scratch reuse saves {:.1}% per call\n",
        "",
        base64.median_ns / wide.median_ns,
        base64_1.median_ns / wide_1.median_ns,
        (1.0 - wide.median_ns / fresh.median_ns) * 100.0
    );
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (batch, iters) = if quick { (1024usize, 10usize) } else { (8192, 30) };
    let mut report = BenchReport::new("sim");

    // Primary: the jets-default config (acceptance gate subject).
    let (model, tables, netlist) =
        synthesized("bench_jets_default", 16, 5, &[64, 32], 3, 2);
    println!(
        "jets-default: {} LUTs over {} inputs, depth {} (batch {batch})",
        netlist.num_luts(),
        netlist.num_inputs,
        netlist.depth()
    );
    sim_scenarios(&mut report, "jets-default", &netlist, batch, iters, true);

    // Fused vs unfused serving pass on the same model (end-to-end
    // quantize → netlist → dense head → argmax).
    let engine = NetlistEngine::build(&model, &tables).unwrap();
    let mut rng = Rng::new(9);
    let xs: Vec<f32> = (0..batch * 16).map(|_| rng.f32()).collect();
    let b = batch as f64;
    let unfused = bench_n("netlist-unfused/jets-default", iters, || {
        std::hint::black_box(engine.infer_batch_unfused(&xs));
    });
    unfused.report_throughput(b, "inf");
    report.add(&unfused, b, "inf");
    let fused = bench_n("netlist-fused/jets-default", iters, || {
        std::hint::black_box(engine.infer_batch(&xs));
    });
    fused.report_throughput(b, "inf");
    report.add(&fused, b, "inf");
    println!(
        "{:<44} fused decode speedup over unfused: {:.2}x\n",
        "",
        unfused.median_ns / fused.median_ns
    );

    // Stress shape: deeper/wider hep_e-like circuit, no scalar pass.
    let (_, _, hep) = synthesized("bench_hep_e_like", 16, 5, &[64, 64, 64], 4, 2);
    println!(
        "hep_e-like: {} LUTs over {} inputs, depth {} (batch {batch})",
        hep.num_luts(),
        hep.num_inputs,
        hep.depth()
    );
    sim_scenarios(&mut report, "hep_e-like", &hep, batch, iters, false);

    report.finish();
}
