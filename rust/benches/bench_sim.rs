//! Bench: netlist simulation throughput across the three evaluator tiers —
//! scalar `Netlist::eval`, the 64-way word path (`eval_netlist_64`, the
//! pre-wide-plane baseline), and the 256-way levelized plan
//! (`eval_plan`) — plus the fused vs unfused `NetlistEngine` serving pass
//! and the scratch-reuse (allocation) win.
//!
//! Primary subject is the jets-default synthesized model (16 features, 5
//! classes, hidden [64, 32], fan-in 3, 2-bit codes — the
//! `SearchAxes::jets_default` center point); a deeper hep_e-like circuit
//! rides along as a stress shape.  Emits `BENCH_sim.json` via
//! `util::bench::BenchReport` (see that module for the `BENCH_OUT` /
//! `BENCH_BASELINE` / `BENCH_QUICK` contract).

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::NetlistEngine;
use logicnets::sim::{eval_netlist_64, eval_plan, BitMatrix, EvalPlan, SimScratch, SimdTier};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, Netlist, SynthOpts};
use logicnets::train::ModelState;
use logicnets::util::bench::{bench_n, BenchReport};
use logicnets::util::rng::Rng;

fn synthesized(
    name: &str,
    in_f: usize,
    classes: usize,
    hidden: &[usize],
    fanin: usize,
    bw: usize,
    bram_min_bits: usize,
) -> (ExportedModel, ModelTables, Netlist) {
    let man = Manifest::synthetic_topology(name, "jets", in_f, classes, hidden, fanin, bw, 0);
    let st = ModelState::init(&man, 7, PruneMethod::APriori);
    let model = ExportedModel::from_state(&man, &st);
    let tables = ModelTables::generate(&model).unwrap();
    let (netlist, _) = synthesize(
        &model,
        &tables,
        SynthOpts { registers: false, bram_min_bits, ..SynthOpts::default() },
    )
    .unwrap();
    (model, tables, netlist)
}

fn random_planes(netlist: &Netlist, batch: usize, seed: u64) -> (BitMatrix, Vec<Vec<bool>>) {
    let mut rng = Rng::new(seed);
    let mut planes = BitMatrix::new(netlist.num_inputs, batch);
    let rows: Vec<Vec<bool>> = (0..batch)
        .map(|s| {
            let bits: Vec<bool> = (0..netlist.num_inputs).map(|_| rng.f64() < 0.5).collect();
            planes.set_column(s, &bits);
            bits
        })
        .collect();
    (planes, rows)
}

/// Throughput tiers of one netlist: scalar (primary model only), 64-way
/// baseline, 256-way plan (reused + fresh scratch, all-core + 1-core).
/// Scenario names are batch-independent so the regression gate matches
/// them across quick/full runs.
fn sim_scenarios(
    report: &mut BenchReport,
    label: &str,
    netlist: &Netlist,
    batch: usize,
    iters: usize,
    with_scalar: bool,
) {
    let (planes, rows) = random_planes(netlist, batch, 11);
    let plan = EvalPlan::compile(netlist);
    let b = batch as f64;

    if with_scalar {
        let scalar = bench_n(&format!("scalar/{label}"), 3.max(iters / 10), || {
            for row in &rows {
                std::hint::black_box(netlist.eval(row));
            }
        });
        scalar.report_throughput(b, "inf");
        report.add(&scalar, b, "inf");
    }

    let base64 = bench_n(&format!("sim64/{label}"), iters, || {
        std::hint::black_box(eval_netlist_64(netlist, &planes));
    });
    base64.report_throughput(b, "inf");
    report.add(&base64, b, "inf");

    let mut scratch = SimScratch::default();
    let wide = bench_n(&format!("sim256/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut scratch));
    });
    wide.report_throughput(b, "inf");
    report.add(&wide, b, "inf");

    // Satellite: the allocation win from reusing scratch across calls.
    let fresh = bench_n(&format!("sim256-fresh-scratch/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut SimScratch::default()));
    });
    fresh.report_throughput(b, "inf");
    report.add(&fresh, b, "inf");

    std::env::set_var("LOGICNETS_THREADS", "1");
    let base64_1 = bench_n(&format!("sim64-1core/{label}"), iters, || {
        std::hint::black_box(eval_netlist_64(netlist, &planes));
    });
    let mut scratch1 = SimScratch::default();
    let wide_1 = bench_n(&format!("sim256-1core/{label}"), iters, || {
        std::hint::black_box(eval_plan(&plan, &planes, &mut scratch1));
    });
    std::env::remove_var("LOGICNETS_THREADS");
    base64_1.report_throughput(b, "inf");
    report.add(&base64_1, b, "inf");
    wide_1.report_throughput(b, "inf");
    report.add(&wide_1, b, "inf");

    println!(
        "{:<44} wide-plane speedup over 64-way: {:.2}x all-cores, {:.2}x single-core \
         (acceptance target >= 3x); scratch reuse saves {:.1}% per call\n",
        "",
        base64.median_ns / wide.median_ns,
        base64_1.median_ns / wide_1.median_ns,
        (1.0 - wide.median_ns / fresh.median_ns) * 100.0
    );
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let (batch, iters) = if quick { (1024usize, 10usize) } else { (8192, 30) };
    let mut report = BenchReport::new("sim");

    // Primary: the jets-default config (acceptance gate subject).
    let (model, tables, netlist) =
        synthesized("bench_jets_default", 16, 5, &[64, 32], 3, 2, 0);
    println!(
        "jets-default: {} LUTs over {} inputs, depth {} (batch {batch})",
        netlist.num_luts(),
        netlist.num_inputs,
        netlist.depth()
    );
    sim_scenarios(&mut report, "jets-default", &netlist, batch, iters, true);

    // Fused vs unfused serving pass on the same model (end-to-end
    // quantize → netlist → dense head → argmax).
    let engine = NetlistEngine::build(&model, &tables).unwrap();
    let mut rng = Rng::new(9);
    let xs: Vec<f32> = (0..batch * 16).map(|_| rng.f32()).collect();
    let b = batch as f64;
    let unfused = bench_n("netlist-unfused/jets-default", iters, || {
        std::hint::black_box(engine.infer_batch_unfused(&xs));
    });
    unfused.report_throughput(b, "inf");
    report.add(&unfused, b, "inf");
    let fused = bench_n("netlist-fused/jets-default", iters, || {
        std::hint::black_box(engine.infer_batch(&xs));
    });
    fused.report_throughput(b, "inf");
    report.add(&fused, b, "inf");
    println!(
        "{:<44} fused decode speedup over unfused: {:.2}x\n",
        "",
        unfused.median_ns / fused.median_ns
    );

    // Stress shape: deeper/wider hep_e-like circuit, no scalar pass.
    let (_, _, hep) = synthesized("bench_hep_e_like", 16, 5, &[64, 64, 64], 4, 2, 0);
    println!(
        "hep_e-like: {} LUTs over {} inputs, depth {} (batch {batch})",
        hep.num_luts(),
        hep.num_inputs,
        hep.depth()
    );
    sim_scenarios(&mut report, "hep_e-like", &hep, batch, iters, false);

    // SIMD dispatch tiers on the jets-default subject: every tier the
    // host can run, against the same plan and inputs.  Portable is the
    // oracle; the acceptance gate wants the dispatched tier >= portable.
    let (tplanes, _) = random_planes(&netlist, batch, 11);
    let tb = batch as f64;
    let mut tier_rates: Vec<(&'static str, f64)> = Vec::new();
    for tier in SimdTier::supported() {
        let plan_t = EvalPlan::compile_with_tier(&netlist, tier);
        let mut scratch_t = SimScratch::default();
        let t = bench_n(&format!("sim256-tier-{}/jets-default", tier.name()), iters, || {
            std::hint::black_box(eval_plan(&plan_t, &tplanes, &mut scratch_t));
        });
        t.report_throughput(tb, "inf");
        report.add(&t, tb, "inf");
        tier_rates.push((tier.name(), t.median_ns));
    }
    if let Some(&(_, portable_ns)) = tier_rates.first() {
        let (best, best_ns) =
            tier_rates.iter().fold(("portable", portable_ns), |acc, &(n, ns)| {
                if ns < acc.1 {
                    (n, ns)
                } else {
                    acc
                }
            });
        println!(
            "{:<44} dispatched tier {} over portable: {:.2}x (detected: {})\n",
            "",
            best,
            portable_ns / best_ns,
            SimdTier::detect().name()
        );
    }

    // Single-sample level-parallel splitting: a wide single-level circuit
    // (one 2048-neuron hidden layer -> 4096 records in one level) at
    // batch 1, where chunk-level parallelism cannot help, with the
    // per-level split off vs on.  This pair calibrates the
    // LOGICNETS_LEVEL_PAR width threshold.
    let (_, _, wide) = synthesized("bench_wide_level", 16, 5, &[2048], 3, 2, 0);
    println!(
        "wide-level: {} LUTs over {} inputs, depth {} (batch 1)",
        wide.num_luts(),
        wide.num_inputs,
        wide.depth()
    );
    let (wplanes, _) = random_planes(&wide, 1, 13);
    let mut wplan = EvalPlan::compile(&wide);
    let single_iters = (iters * 20).max(100);
    wplan.set_level_parallel(false);
    let mut ws_off = SimScratch::default();
    let lp_off = bench_n("sim256-levelpar-off/wide-1s", single_iters, || {
        std::hint::black_box(eval_plan(&wplan, &wplanes, &mut ws_off));
    });
    lp_off.report_throughput(1.0, "inf");
    report.add(&lp_off, 1.0, "inf");
    wplan.set_level_parallel(true);
    let mut ws_on = SimScratch::default();
    let lp_on = bench_n("sim256-levelpar-on/wide-1s", single_iters, || {
        std::hint::black_box(eval_plan(&wplan, &wplanes, &mut ws_on));
    });
    lp_on.report_throughput(1.0, "inf");
    report.add(&lp_on, 1.0, "inf");
    println!(
        "{:<44} level-parallel single-sample speedup: {:.2}x (heuristic verdict: {})\n",
        "",
        lp_off.median_ns / lp_on.median_ns,
        wplan.level_parallel()
    );

    // BRAM-threshold design through the wide path (no scalar fallback):
    // fanin 3 x 2-bit codes = 6 address bits, so bram_min_bits 6 spills
    // every neuron to a content-bearing BRAM record.
    let (bmodel, btables, bram_nl) =
        synthesized("bench_bram_threshold", 16, 5, &[64, 32], 3, 2, 6);
    println!(
        "bram-threshold: {} LUTs + {} BRAM records over {} inputs (batch {})",
        bram_nl.num_luts(),
        bram_nl.num_brams(),
        bram_nl.num_inputs,
        batch.min(1024)
    );
    let (bplanes, brows) = random_planes(&bram_nl, batch.min(1024), 17);
    let bplan = EvalPlan::compile(&bram_nl);
    let mut bscratch = SimScratch::default();
    // Bit-exactness spot check before timing: wide plan vs scalar eval.
    let bout = eval_plan(&bplan, &bplanes, &mut bscratch);
    for (s, row) in brows.iter().take(64).enumerate() {
        assert_eq!(bout.column(s), bram_nl.eval(row), "bram wide/scalar mismatch at sample {s}");
    }
    let bb = bplanes.samples() as f64;
    let bwide = bench_n("sim256-bram/jets-default", iters, || {
        std::hint::black_box(eval_plan(&bplan, &bplanes, &mut bscratch));
    });
    bwide.report_throughput(bb, "inf");
    report.add(&bwide, bb, "inf");
    let b64 = bench_n("sim64-bram/jets-default", iters, || {
        std::hint::black_box(eval_netlist_64(&bram_nl, &bplanes));
    });
    b64.report_throughput(bb, "inf");
    report.add(&b64, bb, "inf");
    let bscalar = bench_n("scalar-bram/jets-default", 3.max(iters / 10), || {
        for row in brows.iter().take(256) {
            std::hint::black_box(bram_nl.eval(row));
        }
    });
    bscalar.report_throughput(256.0, "inf");
    report.add(&bscalar, 256.0, "inf");
    // And the fused serving pass over the same BRAM circuit.
    let bengine = NetlistEngine::from_netlist(&bmodel, &btables, bram_nl).unwrap();
    let bxs: Vec<f32> = {
        let mut rng = Rng::new(19);
        (0..batch.min(1024) * 16).map(|_| rng.f32()).collect()
    };
    assert_eq!(
        bengine.infer_batch(&bxs),
        bengine.infer_batch_unfused(&bxs),
        "bram fused/unfused mismatch"
    );
    let bfused = bench_n("netlist-fused-bram/jets-default", iters, || {
        std::hint::black_box(bengine.infer_batch(&bxs));
    });
    bfused.report_throughput(bb, "inf");
    report.add(&bfused, bb, "inf");
    println!(
        "{:<44} bram wide-plane speedup over 64-way: {:.2}x, over scalar: {:.2}x\n",
        "",
        b64.median_ns / bwide.median_ns,
        (bscalar.median_ns / 256.0) / (bwide.median_ns / bb)
    );

    report.finish();
}
