//! Bench: truth-table generation (paper §5.1, Table 5.1 regime) —
//! single-neuron cost growth with fan-in bits and whole-layer parallel
//! scaling.

use logicnets::luts::{neuron_table, ModelTables};
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::util::bench::{bench, bench_n};
use logicnets::util::rng::Rng;
use std::time::Duration;

fn neuron(bits: usize, rng: &mut Rng) -> Neuron {
    Neuron {
        inputs: (0..bits).collect(),
        weights: (0..bits).map(|_| rng.normal_f32(0.0, 0.5)).collect(),
        bias: 0.05,
        g: 1.0,
        h: 0.0,
    }
}

fn model(widths: &[usize], in_f: usize, fanin: usize, bw: usize, rng: &mut Rng) -> ExportedModel {
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

fn main() {
    let mut rng = Rng::new(1);
    for bits in [8usize, 12, 16, 18] {
        let nr = neuron(bits, &mut rng);
        let qi = QuantSpec::new(1, 1.0);
        let qo = QuantSpec::new(1, 1.0);
        bench_n(&format!("neuron_table {bits} input bits"), 5, || {
            std::hint::black_box(neuron_table(&nr, qi, qo).unwrap());
        })
        .report();
    }

    // Whole-model generation (paper model E shape), parallel over neurons.
    let m = model(&[64, 64, 64], 16, 4, 2, &mut rng);
    bench("ModelTables::generate (64,64,64) X4 BW2", Duration::from_secs(1), || {
        std::hint::black_box(ModelTables::generate(&m).unwrap());
    })
    .report_throughput(192.0, "tables");
}
