//! Bench: the logic-synthesis simulator (Table 5.2/5.3 regime) —
//! minimization + mapping cost for HEP-sized models.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::synth::{synthesize, OptLevel, SynthOpts};
use logicnets::util::bench::bench_n;
use logicnets::util::rng::Rng;

fn model(widths: &[usize], in_f: usize, fanin: usize, bw: usize, seed: u64) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: rng.normal_f32(0.0, 0.1),
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

fn ablation(widths: &[usize], fanin: usize, bw: usize) {
    use logicnets::synth::mapper::{MapStrategy, Mapper};
    use logicnets::synth::BoolFn;
    use logicnets::synth::Net;
    // Ablation (DESIGN.md design-choice study): hybrid cover+Shannon vs
    // Shannon-only mapping on the same trained-like model.
    let m = model(widths, 16, fanin, bw, 11);
    let tables = ModelTables::generate(&m).unwrap();
    for strategy in [MapStrategy::Hybrid, MapStrategy::ShannonOnly] {
        let lt = tables.layers[0].as_ref().unwrap();
        let bw_in = lt.quant_in.bw;
        let mut mapper = Mapper::with_strategy(m.layers[0].in_f * bw_in, strategy);
        for (nj, t) in lt.tables.iter().enumerate() {
            let nr = &m.layers[0].neurons[nj];
            let nets: Vec<Net> = nr
                .inputs
                .iter()
                .flat_map(|&j| (0..bw_in).map(move |b| Net::Input((j * bw_in + b) as u32)))
                .collect();
            for bit in 0..t.out_bits {
                let f = BoolFn::new(t.in_bits, t.output_bit_fn(bit));
                mapper.map_fn(&f, &nets);
            }
        }
        println!(
            "ablation {strategy:?}: layer0 of X{fanin} BW{bw} -> {} LUTs",
            mapper.netlist.num_luts()
        );
    }
}

/// Optimizer pipeline cost and LUT savings per level (the tentpole metric:
/// `NetlistEngine` serving throughput scales with LUT count).
fn opt_sweep(label: &str, widths: &[usize], fanin: usize, bw: usize, iters: usize) {
    let m = model(widths, 16, fanin, bw, 7);
    let tables = ModelTables::generate(&m).unwrap();
    let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };
    let (_, plain) = synthesize(&m, &tables, base).unwrap();
    for level in [OptLevel::Structural, OptLevel::Full] {
        let mut report = None;
        let r = bench_n(&format!("synth+opt({}) {label}", level.name()), iters, || {
            let (_, rep) =
                synthesize(&m, &tables, SynthOpts { opt: level, ..base }).unwrap();
            report = Some(rep);
        });
        r.report();
        let rep = report.unwrap();
        println!(
            "{:<44} {} -> {} LUTs ({:.2}x opt, {} rounds; unopt {})",
            "", rep.pre_opt_luts, rep.luts, rep.opt_reduction, rep.opt_rounds, plain.luts
        );
    }
}

fn main() {
    ablation(&[64, 32, 32], 5, 2);

    for (label, widths, fanin, bw, iters) in [
        ("hep_c-like (64,32,32) X3 BW2", vec![64usize, 32, 32], 3usize, 2usize, 10),
        ("hep_e-like (64,64,64) X4 BW2", vec![64, 64, 64], 4, 2, 5),
        ("t53_b-like (64,32,32) X5 BW2", vec![64, 32, 32], 5, 2, 3),
    ] {
        let m = model(&widths, 16, fanin, bw, 7);
        let tables = ModelTables::generate(&m).unwrap();
        let mut report = None;
        let r = bench_n(&format!("synthesize {label}"), iters, || {
            let (_, rep) = synthesize(&m, &tables, SynthOpts::default()).unwrap();
            report = Some(rep);
        });
        r.report();
        let rep = report.unwrap();
        println!(
            "{:<44} {} LUTs (analytical {}, {:.2}x), depth {}",
            "", rep.luts, rep.analytical_luts, rep.reduction, rep.depth
        );
        opt_sweep(label, &widths, fanin, bw, iters.min(3));
    }
}
