//! Bench: analytical LUT-cost model (Table 2.1 / 6.1 regime).

use logicnets::cost;
use logicnets::util::bench::bench;
use std::time::Duration;

fn main() {
    bench("lut_cost closed-form (N=6..20, M=1..4)", Duration::from_millis(300), || {
        let mut acc = 0u64;
        for n in 6..=20 {
            for m in 1..=4 {
                acc = acc.wrapping_add(cost::lut_cost(n, m));
            }
        }
        std::hint::black_box(acc);
    })
    .report();

    bench("static_map_row table (fan-in 6..11)", Duration::from_millis(300), || {
        for f in 6..=11 {
            std::hint::black_box(cost::static_map_row(f));
        }
    })
    .report();

    bench("model cost: HEP model A layer breakdown", Duration::from_millis(300), || {
        let layers = [
            (64usize, Some(3usize), 3usize, 3usize, 16usize),
            (64, Some(3), 3, 3, 64),
            (64, Some(3), 3, 3, 64),
            (5, None, 3, 3, 64),
        ];
        std::hint::black_box(cost::mlp_cost(&layers));
    })
    .report();
}
