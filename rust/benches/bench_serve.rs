//! Bench: the LUT inference engine + batching router — the paper's
//! extreme-throughput claim scaled to this testbed (POLYBiNN reports 100M
//! MNIST FPS on FPGA; our CPU software model targets >=1M inf/s on
//! HEP-sized nets, single core).

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::engine::InferScratch;
use logicnets::serve::router::{Budget, ModelMeta, ZooServer};
use logicnets::serve::zoo::calibrate_latency;
use logicnets::serve::{Backend, LutEngine, NetlistEngine, Server, ServerConfig};
use logicnets::util::bench::bench;
use logicnets::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn hep_like_model_widths(seed: u64, widths: &[usize]) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = 16usize;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(2, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, 4);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(2, 2.0), true));
        prev = w;
    }
    // dense head
    let neurons = (0..5)
        .map(|_| {
            let inputs: Vec<usize> = (0..prev).collect();
            Neuron {
                inputs: inputs.clone(),
                weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.3)).collect(),
                bias: 0.0,
                g: 1.0,
                h: 0.0,
            }
        })
        .collect();
    layers.push(ExportedLayer::uniform(neurons, prev, QuantSpec::new(2, 2.0), QuantSpec::new(4, 4.0), false));
    let mut act_widths = vec![16];
    act_widths.extend_from_slice(widths);
    ExportedModel {
        layers,
        in_features: 16,
        classes: 5,
        skips: 0,
        act_widths,
    }
}

fn hep_like_model(seed: u64) -> ExportedModel {
    hep_like_model_widths(seed, &[64, 64, 64])
}

fn main() {
    let model = hep_like_model(1);
    let tables = ModelTables::generate(&model).unwrap();
    let engine = Arc::new(LutEngine::build(&model, &tables).unwrap());
    let mut rng = Rng::new(9);
    let batch = 1024usize;
    let xs: Vec<f32> = (0..batch * 16).map(|_| rng.f32()).collect();

    let mut scratch = InferScratch::default();
    let one: Vec<f32> = xs[..16].to_vec();
    bench("engine single inference (hep_e-like)", Duration::from_millis(500), || {
        std::hint::black_box(engine.infer(&one, &mut scratch));
    })
    .report_throughput(1.0, "inf");

    bench("engine batch 1024 (single core)", Duration::from_millis(800), || {
        std::hint::black_box(engine.infer_batch(&xs));
    })
    .report_throughput(batch as f64, "inf");

    bench("engine batch 1024 (all cores)", Duration::from_millis(800), || {
        std::hint::black_box(engine.infer_batch_par(&xs));
    })
    .report_throughput(batch as f64, "inf");

    // Second backend: the synthesized netlist itself, bitsliced 64-way.
    let netlist = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
    println!("netlist backend: {} mapped LUTs", netlist.num_luts());
    bench("netlist batch 1024 (bitsliced)", Duration::from_millis(800), || {
        std::hint::black_box(netlist.infer_batch(&xs));
    })
    .report_throughput(batch as f64, "inf");

    // Optimized netlist backend: serving throughput scales with LUT count,
    // so the pass pipeline translates directly into inferences/s.
    let opt_netlist = Arc::new(
        NetlistEngine::build_opt(&model, &tables, logicnets::synth::OptLevel::Full).unwrap(),
    );
    println!(
        "netlist backend (opt=full): {} mapped LUTs ({} unoptimized)",
        opt_netlist.num_luts(),
        netlist.num_luts()
    );
    bench("netlist(opt) batch 1024 (bitsliced)", Duration::from_millis(800), || {
        std::hint::black_box(opt_netlist.infer_batch(&xs));
    })
    .report_throughput(batch as f64, "inf");

    // Router path with 8 concurrent clients.
    let server = Server::start(
        engine.clone(),
        ServerConfig { workers: 4, max_batch: 64, ..Default::default() },
    );
    let per = 4000usize;
    let r = bench("router 8 clients x 4000 req", Duration::from_millis(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let server = &server;
                let xs = &xs;
                s.spawn(move || {
                    let mut rng = Rng::new(t as u64);
                    for _ in 0..per / 8 {
                        let i = rng.below(batch);
                        server.infer(xs[i * 16..(i + 1) * 16].to_vec());
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    let st = server.stats();
    println!(
        "{:<44} p50 {:.0}us p95 {:.0}us p99 {:.0}us fill {:.1}",
        "", st.p50_us, st.p95_us, st.p99_us, st.mean_batch
    );
    server.shutdown();

    // Same router, netlist backend selected.
    let server = Server::start(
        netlist,
        ServerConfig { workers: 4, max_batch: 64, ..Default::default() },
    );
    let r = bench("router (netlist) 8 clients x 4000 req", Duration::from_millis(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let server = &server;
                let xs = &xs;
                s.spawn(move || {
                    let mut rng = Rng::new(100 + t as u64);
                    for _ in 0..per / 8 {
                        let i = rng.below(batch);
                        server.infer(xs[i * 16..(i + 1) * 16].to_vec());
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    let st = server.stats();
    println!(
        "{:<44} p50 {:.0}us p95 {:.0}us p99 {:.0}us fill {:.1}",
        "", st.p50_us, st.p95_us, st.p99_us, st.mean_batch
    );
    server.shutdown();

    // Zoo scenario: budget routing across a cheap and an expensive
    // netlist behind per-model worker pools.  Calibrated p99s feed the
    // router exactly like a DSE-emitted zoo.json would; traffic is an
    // even mix of unbudgeted (best-quality) and strict-latency requests.
    let small_model = hep_like_model_widths(2, &[16]);
    let small_tables = ModelTables::generate(&small_model).unwrap();
    let small = Arc::new(NetlistEngine::build(&small_model, &small_tables).unwrap());
    let big = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
    let (s50, s99) = calibrate_latency(&*small, &xs[..16 * 64], 200);
    let (b50, b99) = calibrate_latency(&*big, &xs[..16 * 64], 200);
    println!(
        "zoo calibration: small {} LUTs p50 {:.1}us p99 {:.1}us | big {} LUTs p50 {:.1}us p99 {:.1}us",
        small.num_luts(),
        s50,
        s99,
        big.num_luts(),
        b50,
        b99
    );
    let zoo = ZooServer::start(
        vec![
            (
                ModelMeta {
                    name: "small".into(),
                    luts: small.num_luts() as u64,
                    brams: 0,
                    quality: 60.0,
                    p50_us: s50,
                    p99_us: s99,
                },
                small.clone() as Arc<dyn Backend>,
            ),
            (
                ModelMeta {
                    name: "big".into(),
                    luts: big.num_luts() as u64,
                    brams: 0,
                    quality: 90.0,
                    p50_us: b50,
                    p99_us: b99,
                },
                big.clone() as Arc<dyn Backend>,
            ),
        ],
        &ServerConfig { workers: 2, max_batch: 64, ..Default::default() },
    )
    .unwrap();
    let strict = Budget::latency_us(s99);
    let r = bench("zoo router 8 clients x 4000 req (50% budgeted)", Duration::from_millis(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let zoo = &zoo;
                let xs = &xs;
                let strict = &strict;
                s.spawn(move || {
                    let mut rng = Rng::new(200 + t as u64);
                    for k in 0..per / 8 {
                        let i = rng.below(batch);
                        let budget = if k % 2 == 0 { Budget::none() } else { *strict };
                        let _ = zoo.infer(xs[i * 16..(i + 1) * 16].to_vec(), &budget);
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    for m in zoo.stats() {
        println!(
            "{:<12} routed {:>8}  completed {:>8}  p50 {:.0}us p99 {:.0}us fill {:.1}",
            m.name, m.routed, m.stats.completed, m.stats.p50_us, m.stats.p99_us, m.stats.mean_batch
        );
    }
    println!("zoo fallbacks: {}", zoo.fallbacks());
    zoo.shutdown();
}
