//! Bench: the LUT inference engine + batching router — the paper's
//! extreme-throughput claim scaled to this testbed (POLYBiNN reports 100M
//! MNIST FPS on FPGA; our CPU software model targets >=1M inf/s on
//! HEP-sized nets, single core).
//!
//! Emits `BENCH_serve.json` (throughput per scenario, router latency
//! percentiles) via `util::bench::BenchReport`; see that module for the
//! `BENCH_OUT` / `BENCH_BASELINE` / `BENCH_QUICK` environment contract.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::engine::InferScratch;
use logicnets::serve::router::{Budget, ModelMeta, ZooServer};
use logicnets::serve::zoo::calibrate_latency;
use logicnets::serve::{Backend, LutEngine, NetlistEngine, Server, ServerConfig};
use logicnets::util::bench::{bench, BenchReport};
use logicnets::util::json::Json;
use logicnets::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn hep_like_model_widths(seed: u64, widths: &[usize]) -> ExportedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut prev = 16usize;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(2, if k == 0 { 1.0 } else { 2.0 });
        let neurons = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, 4);
                Neuron {
                    inputs: inputs.clone(),
                    weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect(),
                    bias: 0.0,
                    g: 1.0,
                    h: 0.0,
                }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(2, 2.0), true));
        prev = w;
    }
    // dense head
    let neurons = (0..5)
        .map(|_| {
            let inputs: Vec<usize> = (0..prev).collect();
            Neuron {
                inputs: inputs.clone(),
                weights: inputs.iter().map(|_| rng.normal_f32(0.0, 0.3)).collect(),
                bias: 0.0,
                g: 1.0,
                h: 0.0,
            }
        })
        .collect();
    layers.push(ExportedLayer::uniform(neurons, prev, QuantSpec::new(2, 2.0), QuantSpec::new(4, 4.0), false));
    let mut act_widths = vec![16];
    act_widths.extend_from_slice(widths);
    ExportedModel {
        layers,
        in_features: 16,
        classes: 5,
        skips: 0,
        act_widths,
    }
}

fn hep_like_model(seed: u64) -> ExportedModel {
    hep_like_model_widths(seed, &[64, 64, 64])
}

/// Router percentile stats as a report scenario (plus throughput so the
/// regression gate covers the router path too).
fn add_router_stats(
    report: &mut BenchReport,
    name: &str,
    st: &logicnets::serve::ServerStats,
    throughput: f64,
) {
    report.add_with(
        name,
        vec![
            ("throughput_per_s", Json::num(throughput)),
            ("p50_us", Json::num(st.p50_us)),
            ("p95_us", Json::num(st.p95_us)),
            ("p99_us", Json::num(st.p99_us)),
            ("mean_batch", Json::num(st.mean_batch)),
        ],
    );
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let ms = |full: u64| Duration::from_millis(if quick { full / 4 } else { full });
    let mut report = BenchReport::new("serve");
    let model = hep_like_model(1);
    let tables = ModelTables::generate(&model).unwrap();
    let engine = Arc::new(LutEngine::build(&model, &tables).unwrap());
    let mut rng = Rng::new(9);
    let batch = 1024usize;
    let xs: Vec<f32> = (0..batch * 16).map(|_| rng.f32()).collect();

    let mut scratch = InferScratch::default();
    let one: Vec<f32> = xs[..16].to_vec();
    let r = bench("engine-single/hep_e-like", ms(500), || {
        std::hint::black_box(engine.infer(&one, &mut scratch));
    });
    r.report_throughput(1.0, "inf");
    report.add(&r, 1.0, "inf");

    let r = bench("engine-batch-1core/hep_e-like", ms(800), || {
        std::hint::black_box(engine.infer_batch(&xs));
    });
    r.report_throughput(batch as f64, "inf");
    report.add(&r, batch as f64, "inf");

    let r = bench("engine-batch-par/hep_e-like", ms(800), || {
        std::hint::black_box(engine.infer_batch_par(&xs));
    });
    r.report_throughput(batch as f64, "inf");
    report.add(&r, batch as f64, "inf");

    // Second backend: the synthesized netlist itself through the fused
    // wide-plane pass (plus the pre-fusion 64-way path as baseline).
    let netlist = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
    println!("netlist backend: {} mapped LUTs", netlist.num_luts());
    let unfused = bench("netlist-batch-unfused/hep_e-like", ms(800), || {
        std::hint::black_box(netlist.infer_batch_unfused(&xs));
    });
    unfused.report_throughput(batch as f64, "inf");
    report.add(&unfused, batch as f64, "inf");
    let fused = bench("netlist-batch/hep_e-like", ms(800), || {
        std::hint::black_box(netlist.infer_batch(&xs));
    });
    fused.report_throughput(batch as f64, "inf");
    report.add(&fused, batch as f64, "inf");
    println!(
        "{:<44} fused decode speedup over unfused: {:.2}x",
        "",
        unfused.median_ns / fused.median_ns
    );

    // Optimized netlist backend: serving throughput scales with LUT count,
    // so the pass pipeline translates directly into inferences/s.
    let opt_netlist = Arc::new(
        NetlistEngine::build_opt(&model, &tables, logicnets::synth::OptLevel::Full).unwrap(),
    );
    println!(
        "netlist backend (opt=full): {} mapped LUTs ({} unoptimized)",
        opt_netlist.num_luts(),
        netlist.num_luts()
    );
    let r = bench("netlist-opt-batch/hep_e-like", ms(800), || {
        std::hint::black_box(opt_netlist.infer_batch(&xs));
    });
    r.report_throughput(batch as f64, "inf");
    report.add(&r, batch as f64, "inf");

    // Router path with 8 concurrent clients.
    let server = Server::start(engine.clone(), ServerConfig { workers: 4, ..Default::default() });
    let per = if quick { 1000usize } else { 4000 };
    let r = bench("router-8-clients/tables", ms(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let server = &server;
                let xs = &xs;
                s.spawn(move || {
                    let mut rng = Rng::new(t as u64);
                    for _ in 0..per / 8 {
                        let i = rng.below(batch);
                        server.infer(xs[i * 16..(i + 1) * 16].to_vec());
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    let st = server.stats();
    println!(
        "{:<44} p50 {:.0}us p95 {:.0}us p99 {:.0}us fill {:.1}",
        "", st.p50_us, st.p95_us, st.p99_us, st.mean_batch
    );
    add_router_stats(&mut report, "router-8-clients/tables", &st, per as f64 / (r.median_ns / 1e9));
    server.shutdown();

    // Same router, netlist backend selected.
    let server = Server::start(netlist, ServerConfig { workers: 4, ..Default::default() });
    let r = bench("router-8-clients/netlist", ms(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let server = &server;
                let xs = &xs;
                s.spawn(move || {
                    let mut rng = Rng::new(100 + t as u64);
                    for _ in 0..per / 8 {
                        let i = rng.below(batch);
                        server.infer(xs[i * 16..(i + 1) * 16].to_vec());
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    let st = server.stats();
    println!(
        "{:<44} p50 {:.0}us p95 {:.0}us p99 {:.0}us fill {:.1}",
        "", st.p50_us, st.p95_us, st.p99_us, st.mean_batch
    );
    add_router_stats(&mut report, "router-8-clients/netlist", &st, per as f64 / (r.median_ns / 1e9));
    server.shutdown();

    // Zoo scenario: budget routing across a cheap and an expensive
    // netlist behind per-model worker pools.  Calibrated p99s feed the
    // router exactly like a DSE-emitted zoo.json would; traffic is an
    // even mix of unbudgeted (best-quality) and strict-latency requests.
    let small_model = hep_like_model_widths(2, &[16]);
    let small_tables = ModelTables::generate(&small_model).unwrap();
    let small = Arc::new(NetlistEngine::build(&small_model, &small_tables).unwrap());
    let big = Arc::new(NetlistEngine::build(&model, &tables).unwrap());
    let (s50, s99) = calibrate_latency(&*small, &xs[..16 * 64], 200);
    let (b50, b99) = calibrate_latency(&*big, &xs[..16 * 64], 200);
    println!(
        "zoo calibration: small {} LUTs p50 {:.1}us p99 {:.1}us | big {} LUTs p50 {:.1}us p99 {:.1}us",
        small.num_luts(),
        s50,
        s99,
        big.num_luts(),
        b50,
        b99
    );
    let zoo = ZooServer::start(
        vec![
            (
                ModelMeta {
                    name: "small".into(),
                    luts: small.num_luts() as u64,
                    brams: 0,
                    quality: 60.0,
                    p50_us: s50,
                    p99_us: s99,
                },
                small.clone() as Arc<dyn Backend>,
            ),
            (
                ModelMeta {
                    name: "big".into(),
                    luts: big.num_luts() as u64,
                    brams: 0,
                    quality: 90.0,
                    p50_us: b50,
                    p99_us: b99,
                },
                big.clone() as Arc<dyn Backend>,
            ),
        ],
        &ServerConfig { workers: 2, ..Default::default() },
    )
    .unwrap();
    let strict = Budget::latency_us(s99);
    let r = bench("zoo-router-8-clients/50pct-budgeted", ms(1200), || {
        std::thread::scope(|s| {
            for t in 0..8usize {
                let zoo = &zoo;
                let xs = &xs;
                let strict = &strict;
                s.spawn(move || {
                    let mut rng = Rng::new(200 + t as u64);
                    for k in 0..per / 8 {
                        let i = rng.below(batch);
                        let budget = if k % 2 == 0 { Budget::none() } else { *strict };
                        let _ = zoo.infer(xs[i * 16..(i + 1) * 16].to_vec(), &budget);
                    }
                });
            }
        });
    });
    r.report_throughput(per as f64, "inf");
    report.add_with(
        "zoo-router-8-clients/50pct-budgeted",
        vec![("throughput_per_s", Json::num(per as f64 / (r.median_ns / 1e9)))],
    );
    for m in zoo.stats() {
        println!(
            "{:<12} routed {:>8}  completed {:>8}  p50 {:.0}us p99 {:.0}us fill {:.1}",
            m.name, m.routed, m.stats.completed, m.stats.p50_us, m.stats.p99_us, m.stats.mean_batch
        );
    }
    println!("zoo fallbacks: {}", zoo.fallbacks());
    zoo.shutdown();
    report.finish();
}
