//! Bench: one PJRT train-step roundtrip (the L3 training driver hot loop).
//! Skips when artifacts are missing (`make artifacts`).

use logicnets::hep;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::train::{train, ModelState, TrainOpts};
use logicnets::util::bench::bench_n;

fn main() {
    let dir = artifacts_dir();
    for name in ["spike_tiny", "hep_e"] {
        if !Artifact::exists(&dir, name) {
            println!("SKIP bench_train: artifact {name} missing (run `make artifacts`)");
            continue;
        }
        let rt = Runtime::cpu().unwrap();
        let art = Artifact::load(&rt, &dir, name).unwrap();
        let man = art.manifest.clone();
        let ds = hep::jets(4 * man.batch, 3);
        let r = bench_n(&format!("train 10 steps ({name})"), 5, || {
            let mut state = ModelState::init(&man, 1, PruneMethod::APriori);
            let opts = TrainOpts {
                steps: 10,
                log_every: 100,
                ..TrainOpts::from_manifest(&man)
            };
            std::hint::black_box(train(&art, &mut state, &ds, &opts).unwrap());
        });
        r.report();
        println!("{:<44} {:.2} ms/step", "", r.median_ns / 1e6 / 10.0);
    }
}
