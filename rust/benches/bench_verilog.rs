//! Bench: Verilog emission (regenerates the shape of Table 5.1 — file size
//! and generation time exploding exponentially with fan-in bits).

use logicnets::luts::neuron_table;
use logicnets::nn::{Neuron, QuantSpec};
use logicnets::util::bench::bench_n;
use logicnets::util::rng::Rng;
use logicnets::verilog::neuron_module;

fn main() {
    let mut rng = Rng::new(3);
    println!("Table 5.1 regime — single-neuron .v emission:");
    for bits in [12usize, 15, 16, 18] {
        let nr = Neuron {
            inputs: (0..bits).collect(),
            weights: (0..bits).map(|_| rng.normal_f32(0.0, 0.5)).collect(),
            bias: 0.05,
            g: 1.0,
            h: 0.0,
        };
        let table = neuron_table(&nr, QuantSpec::new(1, 1.0), QuantSpec::new(1, 1.0)).unwrap();
        let mut size = 0usize;
        let r = bench_n(&format!("neuron_module {bits} bits"), 3, || {
            let text = neuron_module("LUT_B", &table);
            size = text.len();
            std::hint::black_box(&text);
        });
        r.report();
        println!("{:<44} file size {:.2} MB", "", size as f64 / 1e6);
    }
}
