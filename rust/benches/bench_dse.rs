//! Bench: DSE generator + cost-gate throughput.
//!
//! The search contract is that screening is effectively free — the gate
//! must price >= 10k candidates/sec (it actually does orders of magnitude
//! more) so search cost is dominated by training, never by pricing.

use logicnets::dse::search::{
    gate_screen_rate, generate, CostGate, SearchAxes, GATE_RATE_FLOOR,
};
use logicnets::util::bench::bench;
use std::time::Duration;

fn main() {
    let axes = SearchAxes::jets_default();
    let n = axes.num_candidates();

    // Generator alone: full cross product + deterministic shuffle.
    let r = bench("dse generate (full axis product)", Duration::from_millis(300), || {
        std::hint::black_box(generate(&axes, 1, usize::MAX));
    });
    r.report_throughput(n as f64, "candidates");

    // Gate alone over a pre-generated list (the steady-state screen loop).
    let cands = generate(&axes, 1, usize::MAX);
    let gate = CostGate { budget_luts: 30_000 };
    let r = bench("dse cost gate (price + admit)", Duration::from_millis(300), || {
        let mut admitted = 0usize;
        for c in &cands {
            if gate.admits(gate.price(c, 16, 5)) {
                admitted += 1;
            }
        }
        std::hint::black_box(admitted);
    });
    r.report_throughput(cands.len() as f64, "candidates");

    // End to end: generate + price + admit, the `explore` startup path.
    let r = bench("dse generate + gate (end to end)", Duration::from_millis(300), || {
        let mut admitted = 0usize;
        for c in generate(&axes, 1, usize::MAX) {
            if gate.admits(gate.price(&c, 16, 5)) {
                admitted += 1;
            }
        }
        std::hint::black_box(admitted);
    });
    r.report_throughput(n as f64, "candidates");

    // The ISSUE-level floor, asserted so `cargo bench` runs double as a
    // regression check (same measurement the CI smoke gate uses).
    let screened = gate_screen_rate(&cands, &gate, 16, 5, Duration::from_millis(200));
    println!("gate screening rate: {screened:.0} candidates/sec (floor {GATE_RATE_FLOOR})");
    assert!(
        screened >= GATE_RATE_FLOOR,
        "cost gate regressed below {GATE_RATE_FLOOR} candidates/sec: {screened:.0}"
    );
}
