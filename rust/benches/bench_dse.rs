//! Bench: DSE generator + cost-gate throughput.
//!
//! The search contract is that screening is effectively free — the gate
//! must price >= 10k candidates/sec (it actually does orders of magnitude
//! more) so search cost is dominated by training, never by pricing.

use logicnets::dse::search::{
    gate_screen_rate, generate, CostGate, SearchAxes, GATE_RATE_FLOOR,
};
use logicnets::util::bench::bench;
use std::time::Duration;

fn main() {
    // The default grid now sweeps the skip-connection and pyramid-taper
    // axes too — skip-widened `in_f` pricing is part of the measured loop.
    let axes = SearchAxes::jets_default();
    let n = axes.num_candidates();
    // Generated once: the list is deterministic, so it doubles as the
    // pool-size source and the gate-loop input below.
    let cands = generate(&axes, 1, usize::MAX);
    let pool = cands.len();

    // Generator alone: full cross product + dedup + deterministic shuffle.
    let r = bench("dse generate (full axis product)", Duration::from_millis(300), || {
        std::hint::black_box(generate(&axes, 1, usize::MAX));
    });
    r.report_throughput(pool as f64, "candidates");

    // Gate alone over a pre-generated list (the steady-state screen loop).
    let n_skip = cands.iter().filter(|c| c.skips > 0).count();
    let n_taper =
        cands.iter().filter(|c| c.hidden.windows(2).any(|w| w[0] != w[1])).count();
    println!(
        "pool: {} candidates ({n_skip} skip-wired, {n_taper} pyramid) from a {n}-point product",
        cands.len()
    );
    assert!(n_skip > 0 && n_taper > 0, "new axes must be in the benched pool");
    let gate = CostGate { budget_luts: 30_000 };
    let r = bench("dse cost gate (price + admit)", Duration::from_millis(300), || {
        let mut admitted = 0usize;
        for c in &cands {
            if gate.admits(gate.price(c, 16, 5)) {
                admitted += 1;
            }
        }
        std::hint::black_box(admitted);
    });
    r.report_throughput(cands.len() as f64, "candidates");

    // End to end: generate + price + admit, the `explore` startup path.
    let r = bench("dse generate + gate (end to end)", Duration::from_millis(300), || {
        let mut admitted = 0usize;
        for c in generate(&axes, 1, usize::MAX) {
            if gate.admits(gate.price(&c, 16, 5)) {
                admitted += 1;
            }
        }
        std::hint::black_box(admitted);
    });
    r.report_throughput(pool as f64, "candidates");

    // The ISSUE-level floor, asserted so `cargo bench` runs double as a
    // regression check (same measurement the CI smoke gate uses).
    let screened = gate_screen_rate(&cands, &gate, 16, 5, Duration::from_millis(200));
    println!("gate screening rate: {screened:.0} candidates/sec (floor {GATE_RATE_FLOOR})");
    assert!(
        screened >= GATE_RATE_FLOOR,
        "cost gate regressed below {GATE_RATE_FLOOR} candidates/sec: {screened:.0}"
    );

    // Conv scenario: a pool swept over the conv axes (kept separate from
    // the default pool above so BENCH_baseline.json stays comparable).
    // Conv pricing walks the exact per-pixel window geometry, so it is
    // orders of magnitude heavier than the closed-form MLP price — the
    // same >= 10k/s floor still must hold for the gate to stay free.
    let mut conv_axes = SearchAxes::jets_default();
    conv_axes.conv_modes = vec!["none".into(), "dense".into(), "dw".into()];
    conv_axes.channels = vec![2, 4];
    let conv_cands = generate(&conv_axes, 1, usize::MAX);
    let n_conv = conv_cands.iter().filter(|c| c.conv.is_some()).count();
    println!("conv pool: {} candidates ({n_conv} conv-wired)", conv_cands.len());
    assert!(n_conv > 0, "conv axes must be in the benched pool");
    let r = bench("dse cost gate (conv axes)", Duration::from_millis(300), || {
        let mut admitted = 0usize;
        for c in &conv_cands {
            if gate.admits(gate.price(c, 16, 5)) {
                admitted += 1;
            }
        }
        std::hint::black_box(admitted);
    });
    r.report_throughput(conv_cands.len() as f64, "candidates");
    let conv_screened =
        gate_screen_rate(&conv_cands, &gate, 16, 5, Duration::from_millis(200));
    println!(
        "conv gate screening rate: {conv_screened:.0} candidates/sec (floor {GATE_RATE_FLOOR})"
    );
    assert!(
        conv_screened >= GATE_RATE_FLOOR,
        "conv cost gate below {GATE_RATE_FLOOR} candidates/sec: {conv_screened:.0}"
    );
}
