//! MNIST MLP scenario (paper ch. 7): train a 3-layer sparse quantized MLP,
//! compare the three pruning strategies, and report the analytical LUT
//! breakdown of Table 7.1.
//!
//! Run: `make artifacts && cargo run --release --example mnist_mlp [model]`

use logicnets::cost;
use logicnets::metrics;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::train::{evaluate, train, ModelState, TrainOpts};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "mnist_w512_d3".to_string());
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&rt, &artifacts_dir(), &name)?;
    let man = art.manifest.clone();
    let (train_set, test_set) = logicnets::mnist::load_or_synth(9_000, 1_800, 42);
    println!("MNIST ({}) — {} train / {} test", name, train_set.n, test_set.n);

    let costs = cost::manifest_cost(&man);
    println!("analytical LUT breakdown:");
    for c in &costs {
        println!("  {:<4} {:>10}", c.name, c.luts);
    }
    println!("  total {:>8}\n", cost::total_luts(&costs));

    for method in [
        PruneMethod::APriori,
        PruneMethod::Momentum { every: 8, prune_rate: 0.3 },
        PruneMethod::Iterative { every: 8 },
    ] {
        let mut state = ModelState::init(&man, 7, method);
        let mut opts = TrainOpts::from_manifest(&man);
        opts.method = method;
        opts.steps = opts.steps.min(250);
        let log = train(&art, &mut state, &train_set, &opts)?;
        let logits = evaluate(&art, &state, &test_set)?;
        let acc = metrics::accuracy(&logits, &test_set.y, man.classes);
        println!(
            "{:<10} accuracy {:.3}  (final loss {:.3}, {} mask updates, {:.1}s)",
            method.name(),
            acc,
            log.final_loss,
            log.mask_updates,
            log.seconds
        );
    }
    Ok(())
}
