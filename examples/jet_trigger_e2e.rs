//! End-to-end driver (DESIGN.md §5): the LHC L1-trigger scenario.
//!
//! generate jets → train model A through the AOT HLO train step (loss curve
//! logged) → evaluate AUC-ROC per class → fold BN + export → truth tables →
//! functional verification → Verilog emission → logic synthesis (resources
//! + timing) → serve the netlist through the batching router and report
//! throughput/latency.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example jet_trigger_e2e`

use logicnets::luts::ModelTables;
use logicnets::metrics;
use logicnets::nn::ExportedModel;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::serve::{LutEngine, Server, ServerConfig};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, SynthOpts};
use logicnets::train::{evaluate, train, ModelState, TrainOpts};
use logicnets::verilog::{generate, VerilogOpts};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "hep_e".to_string());
    println!("=== LogicNets jet-trigger end-to-end ({model_name}) ===\n");
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&rt, &artifacts_dir(), &model_name)?;
    let man = art.manifest.clone();

    // -- 1. Workload ------------------------------------------------------
    let mut rng = logicnets::util::rng::Rng::new(1);
    let (train_set, test_set) = logicnets::hep::jets(24_000, 42).split(0.2, &mut rng);
    println!("dataset: {} train / {} test jets, {} features", train_set.n, test_set.n, train_set.d);

    // -- 2. Training (L3 driver over the L2/L1 AOT artifact) --------------
    let mut state = ModelState::init(&man, 7, PruneMethod::APriori);
    let opts = TrainOpts { verbose: true, ..TrainOpts::from_manifest(&man) };
    let log = train(&art, &mut state, &train_set, &opts)?;
    println!("\nloss curve (step, loss):");
    for (s, l) in &log.losses {
        println!("  {s:5}  {l:.4}");
    }
    println!("trained {} steps in {:.1}s\n", log.steps, log.seconds);

    // -- 3. Evaluation ------------------------------------------------------
    let logits = evaluate(&art, &state, &test_set)?;
    let acc = metrics::accuracy(&logits, &test_set.y, man.classes);
    let probs = metrics::softmax_rows(&logits, man.classes);
    let aucs = metrics::auc_ovr(&probs, &test_set.y, man.classes);
    println!("accuracy: {acc:.3}");
    for (name, auc) in logicnets::hep::CLASS_NAMES.iter().zip(&aucs) {
        println!("  AUC-ROC {name}: {:.3}", auc);
    }
    let avg_auc = aucs.iter().sum::<f64>() / aucs.len() as f64;
    println!("  avg AUC: {avg_auc:.3}\n");

    // -- 4. Export + truth tables + verification --------------------------
    let model = ExportedModel::from_state(&man, &state);
    let tables = ModelTables::generate(&model)?;
    let mismatches = tables.verify(&model, &test_set.x[..200 * test_set.d]);
    println!(
        "truth tables: {} neurons, {} KiB, functional verification mismatches: {mismatches}",
        tables.num_tables(),
        tables.size_bytes() / 1024
    );
    assert_eq!(mismatches, 0);

    // -- 5. Verilog --------------------------------------------------------
    let proj = generate(&model, &tables, VerilogOpts { registers: true })?;
    let vdir = std::path::Path::new("reports/verilog_e2e").join(&model_name);
    proj.write_to(&vdir)?;
    println!("verilog: {} files, {} bytes -> {}", proj.files.len(), proj.total_bytes, vdir.display());

    // -- 6. Synthesis -------------------------------------------------------
    let (_, rep) = synthesize(&model, &tables, SynthOpts::default())?;
    println!(
        "synthesis: {} LUTs (analytical {}, {:.2}x), {} FF, {} BRAM, depth {}, WNS {:+.2} ns @5ns",
        rep.luts, rep.analytical_luts, rep.reduction, rep.ffs, rep.brams, rep.depth, rep.wns_ns
    );

    // -- 7. Serving ---------------------------------------------------------
    let engine = Arc::new(LutEngine::build(&model, &tables)?);
    // Accuracy through the engine must match the arithmetic path.
    let engine_pred = engine.infer_batch(&test_set.x);
    let engine_acc = engine_pred
        .iter()
        .zip(&test_set.y)
        .filter(|(p, y)| **p == **y as usize)
        .count() as f64
        / test_set.n as f64;
    println!("engine accuracy: {engine_acc:.3} (arithmetic path {acc:.3})");

    // Netlist-backed serving: score the synthesized circuit itself on the
    // full test set through the bitsliced simulator (64 samples per word).
    match logicnets::serve::NetlistEngine::build(&model, &tables) {
        Ok(net) => {
            let net_acc = logicnets::serve::batch_accuracy(&net, &test_set.x, &test_set.y);
            println!(
                "netlist-backed accuracy: {net_acc:.3} ({} mapped LUTs, bitsliced)",
                net.num_luts()
            );
        }
        Err(e) => println!("netlist backend unavailable: {e}"),
    }

    let requests = 200_000usize;
    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < requests {
        let n = (requests - done).min(test_set.n);
        let _ = engine.infer_batch(&test_set.x[..n * test_set.d]);
        done += n;
    }
    println!(
        "raw engine throughput: {:.2e} inferences/s (single core)",
        requests as f64 / t0.elapsed().as_secs_f64()
    );

    let server = Server::start(engine, ServerConfig::default());
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..8usize {
            let server = &server;
            let ds = &test_set;
            s.spawn(move || {
                let mut rng = logicnets::util::rng::Rng::new(t as u64);
                for _ in 0..10_000 {
                    let i = rng.below(ds.n);
                    server.infer(ds.row(i).to_vec());
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let st = server.stats();
    println!(
        "router: {:.2e} inf/s, latency p50 {:.0}us p99 {:.0}us, mean batch {:.1}",
        st.completed as f64 / elapsed,
        st.p50_us,
        st.p99_us,
        st.mean_batch
    );
    server.shutdown();
    println!("\n=== end-to-end complete ===");
    Ok(())
}
