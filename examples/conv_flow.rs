//! Conv-flow smoke gate (no artifacts needed): lower a tiny convolutional
//! topology end to end on the jet-substructure task — native training over
//! the tied per-pixel kernels → `synthesize` at `OptLevel::Full` →
//! design-rule lint (deny-warn) → machine verification against the truth
//! tables → netlist-backed serving — and FAIL (non-zero exit) if any stage
//! regresses:
//!
//! * the trained export must honor the receptive-field contract
//!   (`lint_conv_model`: every tap in range, windows consistent across
//!   pixels),
//! * the truth tables must bit-match the exported arithmetic mirror,
//! * the optimized netlist must lint to zero findings at `Full` and
//!   machine-verify with zero mismatches,
//! * the served `NetlistEngine` must score clearly above the 5-class
//!   chance floor — the conv front-end has to actually learn.
//!
//! CI runs this; locally: `cargo run --release --example conv_flow`.

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::Manifest;
use logicnets::serve::{batch_accuracy, NetlistEngine};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{
    lint_conv_model, lint_netlist, synthesize, verify_netlist, LintOptions, OptLevel, SynthOpts,
};
use logicnets::train::{native, ModelState, TrainOpts};

fn main() -> anyhow::Result<()> {
    // The 16 jet features as a 4x4 single-channel image: one dense conv
    // stage (4 channels, 3x3 SAME kernel), a sparse hidden layer on the
    // flattened map, and a dense head — the same constructor DSE conv
    // candidates and zoo rebuilds share.
    let man = Manifest::synthetic_conv_for_task(
        "conv_flow", "jets", 16, 5, &[8], 3, 2, "dense", 4, 3,
    )?;
    println!(
        "conv_flow manifest: {} layers ({} conv), in {} -> classes {}",
        man.num_layers(),
        man.conv_geoms()?.len(),
        man.in_features,
        man.classes
    );

    // Real training so BN stats, the tied kernels and the head all move:
    // the gate below needs a net that has actually learned.
    let train = logicnets::hep::jets(4_000, 0xC0DE);
    let test = logicnets::hep::jets(2_000, 0xC0DF);
    let mut st = ModelState::init(&man, 0xC0DE, PruneMethod::APriori);
    let mut topts = TrainOpts::from_manifest(&man);
    topts.steps = 120;
    topts.seed = 0xC0DE;
    let t0 = std::time::Instant::now();
    native::train_native(&man, &mut st, &train, &topts)?;
    println!("trained {} steps in {:.1}s", topts.steps, t0.elapsed().as_secs_f64());

    // Gate 1: the trained export honors the receptive-field contract.
    let ex = ExportedModel::from_state(&man, &st);
    let conv_report = lint_conv_model(&man, &ex)?;
    anyhow::ensure!(
        conv_report.is_clean(),
        "trained export fails the conv receptive-field lint:\n{}",
        conv_report.render()
    );

    // Gate 2: truth tables bit-match the exported mirror.
    let tables = ModelTables::generate(&ex)?;
    let mism = tables.verify(&ex, &test.x);
    anyhow::ensure!(mism == 0, "{mism} table/mirror mismatches");

    // Gate 3: synthesize at Full, lint deny-warn, machine-verify.
    let (netlist, stats) = synthesize(
        &ex,
        &tables,
        SynthOpts { registers: false, bram_min_bits: 0, opt: OptLevel::Full, ..SynthOpts::default() },
    )?;
    println!(
        "synthesized: {} -> {} LUTs ({} opt rounds, x{:.2} reduction)",
        stats.pre_opt_luts, stats.luts, stats.opt_rounds, stats.opt_reduction
    );
    let report = lint_netlist(&netlist, &LintOptions::at(OptLevel::Full));
    anyhow::ensure!(report.is_clean(), "optimized conv netlist fails lint:\n{}", report.render());
    let mism = verify_netlist(&ex, &tables, &netlist, 4096, 0xC0DE)?;
    anyhow::ensure!(mism == 0, "{mism} netlist/table mismatches");

    // Gate 4: the served circuit clears the 20% 5-class chance floor.
    let engine = NetlistEngine::from_netlist(&ex, &tables, netlist)?;
    let acc = batch_accuracy(&engine, &test.x, &test.y);
    println!("netlist-served accuracy: {acc:.3}");
    anyhow::ensure!(
        acc >= 0.25,
        "served conv accuracy {acc:.3} not clearly above the 0.20 chance floor"
    );

    println!("conv-flow gate: OK");
    Ok(())
}
