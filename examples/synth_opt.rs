//! Netlist-optimizer demo and regression gate (no artifacts needed): build
//! the bundled example model, synthesize it with and without the
//! optimization pipeline, machine-check equivalence, score both serving
//! backends on synthetic jets, and FAIL (non-zero exit) if the optimizer
//! stops strictly reducing LUTs — CI runs this so LUT-reduction
//! regressions break the build.
//!
//! Run: `cargo run --release --example synth_opt`

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::serve::{batch_accuracy, LutEngine, NetlistEngine};
use logicnets::synth::{lint_netlist, synthesize, verify_netlist, LintOptions, OptLevel, SynthOpts};
use logicnets::util::rng::Rng;

/// The bundled example model: jet-trigger shaped (16 features, 5-class
/// head implied by the last width), with a first layer trained-to-
/// saturation in the way LogicNets nets actually saturate — the regime
/// where the paper (and Constantinides 2019) argue logic optimization
/// must win.  Deterministic seed, so the gate is reproducible.
fn example_model() -> ExportedModel {
    let (in_f, widths, fanin, bw) = (16usize, [32usize, 16, 5], 4usize, 2usize);
    let mut rng = Rng::new(0xE6);
    let mut layers = Vec::new();
    let mut prev = in_f;
    for (k, &w) in widths.iter().enumerate() {
        let qi = QuantSpec::new(bw, if k == 0 { 1.0 } else { 2.0 });
        let neurons: Vec<Neuron> = (0..w)
            .map(|_| {
                let inputs = rng.choose_k(prev, fanin.min(prev));
                let weights = inputs.iter().map(|_| rng.normal_f32(0.0, 0.8)).collect();
                Neuron { inputs, weights, bias: rng.normal_f32(0.0, 0.1), g: 1.0, h: 0.0 }
            })
            .collect();
        layers.push(ExportedLayer::uniform(neurons, prev, qi, QuantSpec::new(bw, 2.0), true));
        prev = w;
    }
    // Saturate the first layer to the extreme codes — the shared recipe
    // the don't-care tests gate on (`ExportedLayer::saturate_binary`).
    layers[0].saturate_binary();
    ExportedModel {
        layers,
        in_features: in_f,
        classes: *widths.last().unwrap(),
        skips: 0,
        act_widths: std::iter::once(in_f).chain(widths.iter().copied()).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let model = example_model();
    let tables = ModelTables::generate(&model)?;
    let base = SynthOpts { registers: false, bram_min_bits: 0, ..SynthOpts::default() };

    let (plain_netlist, plain) = synthesize(&model, &tables, base)?;
    let t0 = std::time::Instant::now();
    let (netlist, opt) =
        synthesize(&model, &tables, SynthOpts { opt: OptLevel::Full, ..base })?;
    println!("example model: {} analytical LUTs", plain.analytical_luts);
    println!("  unoptimized : {} LUTs ({:.2}x vs analytical)", plain.luts, plain.reduction);
    println!(
        "  optimized   : {} -> {} LUTs ({:.2}x, {} rounds, {:.1} ms incl. verification)",
        opt.pre_opt_luts,
        opt.luts,
        opt.opt_reduction,
        opt.opt_rounds,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // Gate 1: the pipeline must strictly reduce the LUT count.
    anyhow::ensure!(
        opt.luts < plain.luts,
        "LUT-reduction regression: optimized {} >= unoptimized {}",
        opt.luts,
        plain.luts
    );

    // Gate 2: sampled table-equivalence of the served netlist (synthesize
    // already checked internally; re-check here so the gate stands alone).
    let mism = verify_netlist(&model, &tables, &netlist, 4096, 0xE6)?;
    anyhow::ensure!(mism == 0, "{mism} mismatches vs the truth-table forward pass");

    // Gate 3: design-rule lint (deny-warn semantics) on both circuits —
    // unoptimized judged at None (dead LUTs are legal pre-opt), optimized
    // judged at Full, where any surviving finding means a pass regressed.
    for (label, nl, at) in
        [("unoptimized", &plain_netlist, OptLevel::None), ("optimized", &netlist, OptLevel::Full)]
    {
        let report = lint_netlist(nl, &LintOptions { opt: at });
        anyhow::ensure!(report.is_clean(), "{label} netlist fails lint:\n{}", report.render());
    }

    // Gate 4: serving the optimized circuit is bit-identical to the table
    // engine on a realistic workload.
    let ds = logicnets::hep::jets(4096, 0xE6);
    let lut = LutEngine::build(&model, &tables)?;
    let net = NetlistEngine::from_netlist(&model, &tables, netlist)?;
    let a = lut.infer_batch(&ds.x);
    let b = net.infer_batch(&ds.x);
    anyhow::ensure!(a == b, "optimized serving diverged from the table engine");
    println!(
        "  serving     : {} jets, accuracy parity {:.3} == {:.3}, bit-identical",
        ds.n,
        batch_accuracy(&lut, &ds.x, &ds.y),
        batch_accuracy(&net, &ds.x, &ds.y)
    );
    println!("synth-opt gate: OK");
    Ok(())
}
