//! Zoo-serving smoke gate (no artifacts needed): run a small automated
//! search with `emit_zoo`, then serve the emitted manifest budget-routed,
//! end to end — and FAIL (non-zero exit) if any stage regresses:
//!
//! * `explore --emit-zoo` must write a `zoo.json` with >= 2 registered
//!   models, every one 3-D (LUTs, quality, latency) non-dominated and
//!   carrying calibrated (> 0) p50/p99 latencies,
//! * `serve --zoo` must rebuild every entry from its checkpoint into a
//!   machine-verified netlist engine,
//! * a strict-latency-budget request and a no-budget request must route
//!   to two *different* registered models,
//! * mixed-budget traffic must complete with sane per-model stats.
//!
//! CI runs this; locally: `cargo run --release --example zoo_serve`.

use logicnets::dse::search::{run_search, SearchAxes, SearchOpts, SearchTask, WidthShape};
use logicnets::dse::{dominates_3d, pareto_frontier_3d};
use logicnets::serve::router::Budget;
use logicnets::serve::zoo::{build_engine, serve_zoo, ZooManifest};
use logicnets::serve::{batch_accuracy, ServerConfig};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::temp_dir().join("logicnets_zoo_smoke");
    // Fresh directory so the search cannot accidentally resume.
    let _ = std::fs::remove_dir_all(&out_dir);

    let task = SearchTask::jets_small(4_000, 31);
    // Wide LUT spread (16- vs 64-neuron layers, 1- vs 2-bit activations)
    // so the emitted frontier has clearly separated cost/quality points.
    let axes = SearchAxes {
        widths: vec![16, 64],
        depths: vec![1, 2],
        fanins: vec![2, 4],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0, 1],
        shapes: vec![WidthShape::Rect, WidthShape::Taper { pct: 50 }],
        conv_modes: vec!["none".to_string()],
        channels: vec![4],
        kernels: vec![3],
    };
    let opts = SearchOpts {
        budget_luts: 60_000,
        rungs: 2,
        base_steps: 20,
        eta: 2,
        seed: 31,
        max_candidates: 8,
        out_dir: out_dir.clone(),
        resume: false,
        // Emit the whole frontier (cap >= candidate pool), so the zoo
        // spans the full cheap-to-best range and budget routing has real
        // choices.
        emit: 8,
        emit_zoo: true,
    };

    let t0 = std::time::Instant::now();
    let out = run_search(&task, &axes, &opts)?;
    println!(
        "smoke search: {} generated / {} admitted, {} emitted, {:.1}s",
        out.generated,
        out.admitted,
        out.emitted.len(),
        t0.elapsed().as_secs_f64()
    );

    // Gate 1: a zoo manifest with at least two registered models.
    let zoo_path = out.zoo_path.ok_or_else(|| anyhow::anyhow!("no zoo.json written"))?;
    let zoo = ZooManifest::load(&zoo_path)?;
    anyhow::ensure!(
        zoo.entries.len() >= 2,
        "zoo needs >= 2 models for budget routing, got {}",
        zoo.entries.len()
    );
    for e in &zoo.entries {
        println!(
            "  zoo entry {}: {} LUTs, quality {:.2}, p50 {:.1}us, p99 {:.1}us",
            e.name, e.luts, e.quality, e.p50_us, e.p99_us
        );
        // Gate 2: calibrated latencies, never the empty-reservoir 0.0.
        anyhow::ensure!(
            e.p50_us > 0.0 && e.p99_us >= e.p50_us,
            "{} has uncalibrated latency",
            e.name
        );
    }
    // Gate 3: every registered entry is 3-D non-dominated.
    let pts = zoo.points();
    for p in &pts {
        for q in &pts {
            anyhow::ensure!(!dominates_3d(q, p), "zoo entry {} dominated by {}", p.name, q.name);
        }
    }
    anyhow::ensure!(pareto_frontier_3d(&pts).len() == pts.len(), "zoo is not its own frontier");

    // Gate 3b: the zoo round-trips — rebuilding every entry's engine from
    // its checkpoint (the exact `serve --zoo` path, skip wiring included)
    // reproduces the netlist-verified accuracy the search recorded.
    for e in &zoo.entries {
        let engine = build_engine(e, &out_dir)?;
        let acc = batch_accuracy(&engine, &task.test.x, &task.test.y);
        anyhow::ensure!(
            (acc - e.netlist_accuracy).abs() < 1e-12,
            "{}: rebuilt accuracy {acc} != recorded {}",
            e.name,
            e.netlist_accuracy
        );
    }

    // Gate 4: the manifest serves — every entry rebuilds from its
    // checkpoint into a verified netlist engine behind its own pool.
    let server = serve_zoo(
        &zoo_path,
        &ServerConfig { workers: 2, max_batch: 16, ..Default::default() },
    )?;
    let cheap = server.models()[0].clone();
    let best = server.best_model().to_string();
    anyhow::ensure!(
        cheap.name != best,
        "cheapest ({}) and best-quality ({best}) models coincide; zoo: {:?}",
        cheap.name,
        zoo.points()
    );

    // Gate 5: a strict-latency-budget request and a no-budget request
    // route to two different registered models.
    let x = task.test.x[..task.test.d].to_vec();
    let strict_budget = Budget::latency_us(cheap.p99_us);
    let (_, strict_model) = server
        .infer(x.clone(), &strict_budget)
        .ok_or_else(|| anyhow::anyhow!("strict-budget request failed"))?;
    let strict_model = strict_model.to_string();
    let (_, free_model) = server
        .infer(x, &Budget::none())
        .ok_or_else(|| anyhow::anyhow!("no-budget request failed"))?;
    let free_model = free_model.to_string();
    println!("routing: strict (p99<={:.1}us) -> {strict_model}, no budget -> {free_model}", cheap.p99_us);
    anyhow::ensure!(strict_model == cheap.name, "strict budget must route to the cheapest model");
    anyhow::ensure!(free_model == best, "no budget must route to the best-quality model");
    anyhow::ensure!(strict_model != free_model, "budget routing hit a single model");

    // Gate 6: mixed-budget traffic completes with sane per-model stats.
    let mut rng = Rng::new(5);
    let n_req = 400usize;
    for k in 0..n_req {
        let i = rng.below(task.test.n);
        let row = task.test.x[i * task.test.d..(i + 1) * task.test.d].to_vec();
        let budget = if k % 2 == 0 { Budget::none() } else { strict_budget };
        anyhow::ensure!(server.infer(row, &budget).is_some(), "request {k} failed");
    }
    let stats = server.stats();
    let routed: u64 = stats.iter().map(|m| m.routed).sum();
    let completed: u64 = stats.iter().map(|m| m.stats.completed).sum();
    anyhow::ensure!(routed == n_req as u64 + 2, "routed {routed} != {}", n_req + 2);
    anyhow::ensure!(completed == n_req as u64 + 2, "completed {completed} != {}", n_req + 2);
    anyhow::ensure!(server.fallbacks() == 0, "unexpected budget fallbacks");
    for m in &stats {
        println!(
            "  served {}: routed {} completed {} live p50 {:.1}us p99 {:.1}us",
            m.name, m.routed, m.stats.completed, m.stats.p50_us, m.stats.p99_us
        );
        if m.routed > 0 {
            anyhow::ensure!(
                m.stats.lat_samples > 0 && m.stats.p50_us > 0.0 && m.stats.p99_us >= m.stats.p50_us,
                "{}: implausible latency stats",
                m.name
            );
        }
    }
    server.shutdown();
    println!("zoo-serve gate: OK");
    Ok(())
}
