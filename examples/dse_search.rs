//! DSE smoke gate (no artifacts needed): run a tiny-budget automated
//! search on the jet-substructure task end to end — generate → cost-gate →
//! successive halving through the native trainer → Pareto archive →
//! frontier emit through `synthesize --opt` + `NetlistEngine` — and FAIL
//! (non-zero exit) if any stage regresses:
//!
//! * the archive must be written and non-empty,
//! * the frontier must be non-empty and strictly non-dominated, and must
//!   contain at least one `skips > 0` or non-uniform-width (pyramid)
//!   candidate — the region the skip/shape axes unlock,
//! * at least one frontier model must synthesize, machine-verify against
//!   its truth tables, and serve through the netlist backend,
//! * re-running with `resume` must perform **zero** retraining,
//! * the cost gate must screen >= 10k candidates/sec.
//!
//! CI runs this; locally: `cargo run --release --example dse_search`.

use logicnets::dse::search::{
    gate_screen_rate, generate, run_search, CostGate, SearchAxes, SearchOpts, SearchTask,
    WidthShape, GATE_RATE_FLOOR,
};
use logicnets::sparsity::prune::PruneMethod;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::temp_dir().join("logicnets_dse_smoke");
    // Fresh directory so the first run cannot accidentally resume.
    let _ = std::fs::remove_dir_all(&out_dir);

    let task = SearchTask::jets_small(4_000, 11);
    // Depth-2 pool over both new axes.  The globally cheapest candidate is
    // a taper (pyramid) topology — tapering strictly narrows later layers
    // and the head — so with the whole pool admitted and trained, the
    // frontier deterministically carries a non-uniform-width point.
    let axes = SearchAxes {
        widths: vec![16, 32],
        depths: vec![2],
        fanins: vec![2, 3],
        bws: vec![1, 2],
        methods: vec![PruneMethod::APriori],
        bram_min_bits: vec![13],
        skips: vec![0, 1],
        shapes: vec![WidthShape::Rect, WidthShape::Taper { pct: 50 }],
        conv_modes: vec!["none".to_string()],
        channels: vec![4],
        kernels: vec![3],
    };
    let opts = SearchOpts {
        budget_luts: 8_000,
        rungs: 2,
        base_steps: 20,
        eta: 2,
        seed: 11,
        // Above the 32-candidate pool, so the whole product trains and the
        // cheapest (taper) topology is guaranteed in.
        max_candidates: 64,
        out_dir: out_dir.clone(),
        resume: false,
        emit: 1,
        emit_zoo: false,
    };

    let t0 = std::time::Instant::now();
    let out = run_search(&task, &axes, &opts)?;
    println!(
        "smoke search: {} generated / {} admitted / {} gated, {} steps, {:.1}s",
        out.generated,
        out.admitted,
        out.gated,
        out.steps_trained,
        t0.elapsed().as_secs_f64()
    );

    // Gate 1: non-empty resumable archive on disk.
    anyhow::ensure!(out.archive_path.exists(), "archive not written");
    let archive = logicnets::dse::search::Archive::load(&out.archive_path)?;
    anyhow::ensure!(!archive.entries.is_empty(), "archive is empty");
    anyhow::ensure!(out.steps_trained > 0, "fresh search trained nothing");

    // Gate 2: non-empty, non-dominated frontier.
    anyhow::ensure!(!out.frontier.is_empty(), "empty Pareto frontier");
    for w in out.frontier.windows(2) {
        anyhow::ensure!(
            w[0].luts <= w[1].luts && w[0].quality < w[1].quality,
            "frontier not monotone: {:?} -> {:?}",
            (w[0].luts, w[0].quality),
            (w[1].luts, w[1].quality)
        );
    }
    // Gate 2b: the new axes reach the frontier — at least one frontier
    // point is a skip-wired or pyramid (non-uniform-width) topology.
    let novel = out
        .frontier
        .iter()
        .filter_map(|p| archive.entries.get(&p.name))
        .filter(|e| e.skips > 0 || e.hidden.windows(2).any(|w| w[0] != w[1]))
        .count();
    println!("frontier: {} point(s), {novel} skip/pyramid", out.frontier.len());
    anyhow::ensure!(
        novel > 0,
        "no skip or pyramid candidate reached the Pareto frontier"
    );

    // Gate 3: a frontier model ended as a verified, servable netlist.
    anyhow::ensure!(!out.emitted.is_empty(), "no frontier model emitted");
    let e = &out.emitted[0];
    anyhow::ensure!(e.mapped_luts > 0, "emitted netlist has no LUTs");
    anyhow::ensure!(
        (e.mapped_luts as u64) <= e.analytical_luts,
        "mapped {} exceeds the analytical bound {}",
        e.mapped_luts,
        e.analytical_luts
    );
    println!(
        "emitted {}: {} -> {} LUTs, netlist accuracy {:.3}",
        e.name, e.analytical_luts, e.mapped_luts, e.netlist_accuracy
    );

    // Gate 4: resume replays the whole search with zero retraining.
    let resumed = run_search(&task, &axes, &SearchOpts { resume: true, ..opts.clone() })?;
    anyhow::ensure!(
        resumed.steps_trained == 0,
        "resume retrained {} steps (must be 0)",
        resumed.steps_trained
    );
    anyhow::ensure!(
        resumed.frontier.len() == out.frontier.len(),
        "resume changed the frontier ({} vs {} points)",
        resumed.frontier.len(),
        out.frontier.len()
    );

    // Gate 5: the cost gate screens >= GATE_RATE_FLOOR candidates/sec
    // (same measurement bench_dse asserts).
    let cands = generate(&axes, 11, usize::MAX);
    let gate = CostGate { budget_luts: opts.budget_luts };
    let rate = gate_screen_rate(
        &cands,
        &gate,
        task.in_features,
        task.classes,
        std::time::Duration::from_millis(100),
    );
    println!("gate screening rate: {rate:.0} candidates/sec");
    anyhow::ensure!(
        rate >= GATE_RATE_FLOOR,
        "gate below {GATE_RATE_FLOOR} candidates/sec: {rate:.0}"
    );

    println!("dse-search gate: OK");
    Ok(())
}
