//! Serving demo: load (or quickly train) a LogicNet, compile it into the
//! truth-table inference engine, and stress the batching router with
//! concurrent clients — the software analogue of the FPGA trigger's
//! initiation-interval-1 datapath.
//!
//! Run: `make artifacts && cargo run --release --example lut_server`

use logicnets::luts::ModelTables;
use logicnets::nn::ExportedModel;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::serve::{LutEngine, Server, ServerConfig};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::train::{train, ModelState, TrainOpts};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "hep_e".to_string());
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&rt, &artifacts_dir(), &name)?;
    let man = art.manifest.clone();
    let mut rng = logicnets::util::rng::Rng::new(1);
    let (train_set, test_set) = logicnets::hep::jets(12_000, 42).split(0.2, &mut rng);

    let mut state = ModelState::init(&man, 7, PruneMethod::APriori);
    let mut opts = TrainOpts::from_manifest(&man);
    opts.steps = opts.steps.min(200);
    train(&art, &mut state, &train_set, &opts)?;

    let model = ExportedModel::from_state(&man, &state);
    let tables = ModelTables::generate(&model)?;
    let engine = Arc::new(LutEngine::build(&model, &tables)?);
    println!(
        "engine ready: {} table neurons, {} KiB of tables",
        tables.num_tables(),
        tables.size_bytes() / 1024
    );

    for (workers, max_batch) in [(1usize, 1usize), (2, 16), (4, 64), (8, 64)] {
        let server = Server::start(
            engine.clone(),
            ServerConfig {
                workers,
                max_batch,
                batch_timeout: Duration::from_micros(100),
                queue_depth: 8192,
            },
        );
        let clients = 8usize;
        let per = 5_000usize;
        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for t in 0..clients {
                let server = &server;
                let ds = &test_set;
                s.spawn(move || {
                    let mut rng = logicnets::util::rng::Rng::new(50 + t as u64);
                    for _ in 0..per {
                        let i = rng.below(ds.n);
                        server.infer(ds.row(i).to_vec());
                    }
                });
            }
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let st = server.stats();
        println!(
            "workers={workers:<2} max_batch={max_batch:<3} -> {:>10.0} inf/s  p50 {:>6.0}us  p99 {:>7.0}us  fill {:>5.1}",
            st.completed as f64 / elapsed,
            st.p50_us,
            st.p99_us,
            st.mean_batch
        );
        server.shutdown();
    }
    Ok(())
}
