//! Telemetry smoke gate (no artifacts needed): exercise the crate-wide
//! observability layer (`logicnets::obs`) against a live zoo server and
//! FAIL (non-zero exit) if the accounting is inconsistent:
//!
//! * with telemetry disabled, observational counters and spans must record
//!   nothing — and must not even register their metrics;
//! * mixed-budget traffic over two models must leave every request-phase
//!   histogram (queue-wait / eval / fused-tail / latency) holding exactly
//!   one sample per routed request, with routed == completed in total;
//! * the exact-histogram latency percentiles must land within one log2
//!   bucket of the reservoir cross-check;
//! * the global snapshot must expose the per-model `serve.*` metrics,
//!   round-trip through its JSON form byte-stably, and be written as
//!   `OBS_serve.json` (`$OBS_OUT`, default `.`) for CI to upload next to
//!   the `BENCH_*.json` artifacts.
//!
//! CI runs this; locally: `cargo run --release --example obs_snapshot`.

use logicnets::luts::ModelTables;
use logicnets::nn::{ExportedLayer, ExportedModel, Neuron, QuantSpec};
use logicnets::obs;
use logicnets::serve::{Backend, Budget, LutEngine, ModelMeta, ServerConfig, ZooServer};
use logicnets::util::rng::Rng;
use std::sync::Arc;

/// Small single-layer model served straight from its truth tables — the
/// gate is about telemetry accounting, not model quality.
fn engine(seed: u64) -> anyhow::Result<Arc<dyn Backend>> {
    let mut rng = Rng::new(seed);
    let neurons: Vec<Neuron> = (0..8)
        .map(|_| {
            let inputs = rng.choose_k(6, 3);
            Neuron {
                inputs: inputs.clone(),
                weights: inputs.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                bias: 0.0,
                g: 1.0,
                h: 0.0,
            }
        })
        .collect();
    let model = ExportedModel {
        layers: vec![ExportedLayer::uniform(
            neurons,
            6,
            QuantSpec::new(2, 1.0),
            QuantSpec::new(2, 2.0),
            true,
        )],
        in_features: 6,
        classes: 8,
        skips: 0,
        act_widths: vec![6],
    };
    let tables = ModelTables::generate(&model)?;
    Ok(Arc::new(LutEngine::build(&model, &tables)?))
}

fn main() -> anyhow::Result<()> {
    // Gate 0: disabled telemetry is inert.  Safe to toggle here (own
    // process); in-crate tests never touch the global flag.
    obs::set_enabled(false);
    anyhow::ensure!(!obs::enabled());
    obs::inc("gate.disabled.count");
    obs::add("gate.disabled.add.count", 5);
    {
        let sp = obs::Span::named("gate.disabled.ns");
        anyhow::ensure!(!sp.is_live(), "span must be inert while telemetry is off");
    }
    anyhow::ensure!(
        obs::snapshot().is_empty(),
        "disabled telemetry must leave the registry empty"
    );
    obs::set_enabled(true);

    // Two models with separated routing metadata: a strict 50us budget
    // admits only "cheap"; unbudgeted requests go to "best".
    let cheap = ModelMeta {
        name: "cheap".to_string(),
        luts: 100,
        brams: 0,
        quality: 80.0,
        p50_us: 20.0,
        p99_us: 50.0,
    };
    let best = ModelMeta {
        name: "best".to_string(),
        luts: 4_000,
        brams: 0,
        quality: 95.0,
        p50_us: 200.0,
        p99_us: 500.0,
    };
    let zoo = ZooServer::start(
        vec![(cheap, engine(3)?), (best, engine(4)?)],
        &ServerConfig {
            workers: 2,
            max_batch: 8,
            obs_prefix: Some("serve".to_string()),
            ..Default::default()
        },
    )?;

    // Mixed-budget traffic from four client threads.
    let n_req = 400usize;
    let strict = Budget::latency_us(50.0);
    std::thread::scope(|s| {
        for t in 0..4usize {
            let zoo = &zoo;
            s.spawn(move || {
                let mut rng = Rng::new(t as u64);
                for k in 0..n_req / 4 {
                    let x: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
                    let budget = if k % 2 == 0 { Budget::none() } else { strict };
                    assert!(zoo.infer(x, &budget).is_some(), "request {k} failed");
                }
            });
        }
    });

    // Gate 1: routed-count totals are consistent end to end.
    let stats = zoo.stats();
    let routed_total: u64 = stats.iter().map(|m| m.routed).sum();
    let completed_total: u64 = stats.iter().map(|m| m.stats.completed).sum();
    anyhow::ensure!(routed_total == n_req as u64, "routed {routed_total} != {n_req}");
    anyhow::ensure!(completed_total == n_req as u64, "completed {completed_total} != {n_req}");
    anyhow::ensure!(zoo.fallbacks() == 0, "unexpected budget fallbacks");

    // Gate 2: every phase histogram holds exactly one sample per routed
    // request — the queue-wait / eval / fused-tail breakdown never loses
    // or double-counts a request.
    for (name, m) in zoo.model_metrics() {
        let routed = stats.iter().find(|s| s.name == name).map(|s| s.routed).unwrap_or(0);
        anyhow::ensure!(routed > 0, "model {name} received no traffic");
        for (phase, h) in [
            ("queue_wait", &m.queue_wait_ns),
            ("eval", &m.eval_ns),
            ("tail", &m.tail_ns),
            ("latency", &m.latency_ns),
        ] {
            anyhow::ensure!(
                h.count() == routed,
                "{name}.{phase}: {} samples != {routed} routed",
                h.count()
            );
        }
        anyhow::ensure!(m.queue_depth.get() == 0, "{name}: queue gauge did not drain");
        anyhow::ensure!(m.batch_fill.count() > 0, "{name}: no batch-fill samples");
    }

    // Gate 3: exact-histogram percentiles within one log2 bucket of the
    // reservoir cross-check (the reservoir held the full stream here).
    for ms in &stats {
        anyhow::ensure!(
            ms.stats.lat_samples as u64 == ms.stats.completed,
            "{}: reservoir lost samples under capacity",
            ms.name
        );
        for (which, hist, res) in [
            ("p50", ms.stats.p50_us, ms.stats.res_p50_us),
            ("p99", ms.stats.p99_us, ms.stats.res_p99_us),
        ] {
            let d = obs::bucket_index((hist * 1e3) as u64) as i64
                - obs::bucket_index((res * 1e3) as u64) as i64;
            anyhow::ensure!(
                d.abs() <= 1,
                "{} {which}: histogram {hist:.1}us vs reservoir {res:.1}us, {d} buckets apart",
                ms.name
            );
        }
        println!(
            "  {}: routed {} p50 {:.1}us (res {:.1}) p99 {:.1}us (res {:.1})",
            ms.name, ms.routed, ms.stats.p50_us, ms.stats.res_p50_us, ms.stats.p99_us,
            ms.stats.res_p99_us
        );
    }

    // Gate 4: the global registry snapshot carries the published serve.*
    // metrics and agrees with the handles.
    let snap = obs::snapshot();
    for ms in &stats {
        for phase in ["queue_wait", "eval", "tail"] {
            let key = format!("serve.{}.{phase}.ns", ms.name);
            let h = snap
                .histogram(&key)
                .ok_or_else(|| anyhow::anyhow!("{key} missing from snapshot"))?;
            anyhow::ensure!(h.count() == ms.routed, "{key}: {} != {}", h.count(), ms.routed);
        }
        let key = format!("serve.{}.routed.count", ms.name);
        anyhow::ensure!(
            snap.counter(&key) == Some(ms.routed),
            "{key}: {:?} != {}",
            snap.counter(&key),
            ms.routed
        );
    }
    anyhow::ensure!(snap.counter("serve.fallbacks.count") == Some(0), "fallback counter");

    // Gate 5: snapshot JSON round-trips byte-stably and ships as the CI
    // telemetry artifact.
    let js = snap.to_json();
    let back = obs::SnapshotReport::from_json(&js)?;
    anyhow::ensure!(
        back.to_json().to_string() == js.to_string(),
        "snapshot JSON is not byte-stable"
    );
    let dir = std::env::var("OBS_OUT").unwrap_or_else(|_| ".".to_string());
    let path = format!("{dir}/OBS_serve.json");
    std::fs::write(&path, js.to_string())?;
    println!("wrote {path}");

    // Gate 6: the `serve --zoo --json` payload is self-consistent.
    let sj = zoo.stats_json();
    anyhow::ensure!(sj.get("zoo").and_then(|v| v.as_str()) == Some("stats"), "zoo marker");
    let models = sj
        .get("models")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| anyhow::anyhow!("stats_json has no models array"))?;
    anyhow::ensure!(models.len() == 2, "expected 2 models, got {}", models.len());
    for mj in models {
        let routed = mj.get("routed").and_then(|v| v.as_f64()).unwrap_or(-1.0);
        let completed = mj.get("completed").and_then(|v| v.as_f64()).unwrap_or(-2.0);
        anyhow::ensure!(routed == completed, "stats_json routed {routed} != completed {completed}");
        anyhow::ensure!(
            mj.get("queue_wait_p99_us").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0,
            "missing phase breakdown in stats_json"
        );
    }

    zoo.shutdown();
    println!("obs-snapshot gate: OK");
    Ok(())
}
