//! Quickstart: train a tiny LogicNet on synthetic jets, export it to truth
//! tables, verify, and synthesize — the whole flow in ~30 lines of API.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use logicnets::luts::ModelTables;
use logicnets::metrics;
use logicnets::nn::ExportedModel;
use logicnets::runtime::{artifacts_dir, Artifact, Runtime};
use logicnets::sparsity::prune::PruneMethod;
use logicnets::synth::{synthesize, SynthOpts};
use logicnets::train::{evaluate, train, ModelState, TrainOpts};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let art = Artifact::load(&rt, &artifacts_dir(), "spike_tiny")?;
    let man = &art.manifest;

    // 1. Data + training through the AOT-compiled train_step.
    let mut rng = logicnets::util::rng::Rng::new(1);
    let (train_set, test_set) = logicnets::hep::jets(16_000, 42).split(0.2, &mut rng);
    let mut state = ModelState::init(man, 7, PruneMethod::APriori);
    let opts = TrainOpts { verbose: true, ..TrainOpts::from_manifest(man) };
    let log = train(&art, &mut state, &train_set, &opts)?;
    println!("trained {} steps in {:.1}s", log.steps, log.seconds);

    // 2. Evaluate via the forward artifact.
    let logits = evaluate(&art, &state, &test_set)?;
    let acc = metrics::accuracy(&logits, &test_set.y, man.classes);
    println!("test accuracy: {acc:.3}");

    // 3. Export neurons as boolean functions and generate truth tables.
    let model = ExportedModel::from_state(man, &state);
    let tables = ModelTables::generate(&model)?;
    println!(
        "{} truth tables, {} KiB",
        tables.num_tables(),
        tables.size_bytes() / 1024
    );

    // 4. Functional verification: tables vs the arithmetic mirror.
    let mismatches = tables.verify(&model, &test_set.x[..100 * test_set.d]);
    assert_eq!(mismatches, 0, "tables must match the folded model exactly");
    println!("functional verification: OK");

    // 5. Logic synthesis: analytical bound vs mapped netlist.
    let (_, report) = synthesize(&model, &tables, SynthOpts::default())?;
    println!(
        "synthesis: {} LUTs (analytical {}), {} FF, WNS {:+.2} ns @5ns",
        report.luts, report.analytical_luts, report.ffs, report.wns_ns
    );
    Ok(())
}
