#!/usr/bin/env bash
# Re-measure the perf baseline (BENCH_baseline.json) on the current machine.
#
# Runs both bench targets in full (non-quick) mode with no gate active,
# then merges their scenario lists into BENCH_baseline.json at the repo
# root.  Run on a quiet machine: the CI gate fails any scenario whose
# throughput drops more than BENCH_MAX_REGRESS (default 20%) below these
# numbers.  Commit the refreshed file together with the change that
# shifted the numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

(cd rust && BENCH_OUT="$out" cargo bench --bench bench_sim)
(cd rust && BENCH_OUT="$out" cargo bench --bench bench_serve)

python3 - "$out" > BENCH_baseline.json <<'PY'
import json, sys, glob, datetime
scenarios = []
for path in sorted(glob.glob(sys.argv[1] + "/BENCH_*.json")):
    with open(path) as f:
        scenarios.extend(json.load(f)["scenarios"])
print(json.dumps({
    "bench": "baseline",
    "note": "Measured baseline (full mode) recorded by scripts/refresh_bench_baseline.sh on "
            + datetime.date.today().isoformat() + ".",
    "scenarios": scenarios,
}, indent=2))
PY
echo "wrote BENCH_baseline.json ($(python3 -c 'import json;print(len(json.load(open("BENCH_baseline.json"))["scenarios"]))') scenarios)"
