#!/usr/bin/env bash
# Re-measure the perf baseline (BENCH_baseline.json) on the current machine.
#
# Runs both bench targets in full (non-quick) mode with no gate active,
# then merges their scenario lists into BENCH_baseline.json at the repo
# root.  Run on a quiet machine: the CI gate fails any scenario whose
# throughput drops more than BENCH_MAX_REGRESS (default 20%) below these
# numbers.  Commit the refreshed file together with the change that
# shifted the numbers.
set -euo pipefail
cd "$(dirname "$0")/.."

out="$(mktemp -d)"
trap 'rm -rf "$out"' EXIT

(cd rust && BENCH_OUT="$out" cargo bench --bench bench_sim)
(cd rust && BENCH_OUT="$out" cargo bench --bench bench_serve)

python3 - "$out" > BENCH_baseline.json <<'PY'
import json, sys, glob, datetime
scenarios, meta = [], {}
for path in sorted(glob.glob(sys.argv[1] + "/BENCH_*.json")):
    with open(path) as f:
        rep = json.load(f)
    scenarios.extend(rep["scenarios"])
    # Every report stamps the same machine provenance (git sha, sim
    # geometry, thread count); carry it into the baseline so the machine
    # note no longer needs to be written by hand.
    meta = rep.get("meta", meta)
note = ("Measured baseline (full mode) recorded by scripts/refresh_bench_baseline.sh on "
        + datetime.date.today().isoformat())
if meta:
    note += (" at commit %s (%s threads, %s lanes)"
             % (str(meta.get("git_sha", "unknown"))[:12],
                int(meta.get("threads", 0)), int(meta.get("lanes", 0))))
print(json.dumps({
    "bench": "baseline",
    "note": note + ".",
    "meta": meta,
    "scenarios": scenarios,
}, indent=2))
PY
echo "wrote BENCH_baseline.json ($(python3 -c 'import json;print(len(json.load(open("BENCH_baseline.json"))["scenarios"]))') scenarios)"
